//! Pooled per-client training state — O(active) server memory at
//! bench-scale fleets.
//!
//! The paper's headline claim (Table I, the 79% reduction vs parallel
//! SFL) rests on the server keeping only the *currently served* clients'
//! LoRA/optimizer state resident while everything else is cold.  The
//! pre-pool numeric `Session` did the opposite: it eagerly built a
//! `ClientState`/`ServerState` pair for every fleet member, so memory
//! grew O(fleet) even when `--max-participants` bounded each round to a
//! handful of clients.  [`StatePool`] makes the reproduction match the
//! system the paper describes:
//!
//! - **Lazy materialization** — a client's state is built on first
//!   participation, derived deterministically from the pool's canonical
//!   *baseline* model (the initial LoRA before round 1, the last
//!   aggregate after).  The materialized state is bit-equal to what
//!   `ClientState::fresh` / `ServerState::fresh` over `split_at(k)`
//!   would have produced, so pooled and eager sessions train
//!   bit-identical trajectories.
//! - **Bounded residency + spill** — at most `max(round cohort,
//!   state_cap)` buffer sets stay resident; cold clients are evicted to
//!   a compact flat-`f32` spill (step counters ride along via the
//!   checkpoint encoders).  Post-aggregation, a spilled client's
//!   LoRA/head equal the baseline by construction, so those spill
//!   segments are dropped entirely and only the Adam moments remain.
//! - **Arena recycling** — evicted buffer sets go to a free list and
//!   are reshaped in place for the next materialization, so the steady
//!   state performs zero `HostTensor` allocations per round (the same
//!   `tensor::alloc_count` discipline as the PR-1 hot path).
//! - **Sparse serialization** — checkpoints list only materialized
//!   clients (`scheme.pool.materialized`); never-seen clients are
//!   reconstructed from the checkpointed baseline on resume, so a
//!   10k-client checkpoint stores a few dozen states, not 10k.
//!
//! `state_cap = 0` selects the eager mode (every client materialized at
//! construction, never evicted) — the pre-pool behavior, kept both as
//! the bench comparison point and as the default for the small paper
//! fleet where pooling has nothing to save.

use crate::checkpoint::{
    encode_u64s, load_adam, load_adapters, load_iter_state, load_tensor_into, one_u64,
    save_adam, save_adapters, save_iter_state,
};
use crate::data::{BatchIter, DataPool};
use crate::lora::AdapterSet;
use crate::model::ModelDims;
use crate::runtime::{ClientState, HeadState, ServerState};
use crate::tensor::{ops, store::ParamStore, HostTensor, TensorData};
use anyhow::{bail, Result};

/// Pool telemetry counters, streamed per round in the jsonl reports and
/// asserted by the memory benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    /// Acquires that found the client already resident (per touch).
    pub hits: u64,
    /// Materializations (fresh derivations + spill reloads).
    pub misses: u64,
    /// Residents pushed out to spill.
    pub evictions: u64,
    /// Currently resident clients.
    pub resident: usize,
    /// Currently spilled clients.
    pub spilled: usize,
    /// Bytes held in resident per-client state buffers right now.
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes` over the pool's lifetime.
    pub peak_resident_bytes: u64,
    /// Bytes held in compact spill payloads right now.
    pub spill_bytes: u64,
}

/// One resident client: training state + its batch iterator, updated in
/// place by the schemes.
#[derive(Debug)]
pub struct ClientSlot {
    pub client: usize,
    pub cs: ClientState,
    pub ss: ServerState,
    pub it: BatchIter,
    /// Transport error-feedback residual over the client-half LoRA
    /// (flat, `cs.lora.param_count()` long) — empty unless the pool was
    /// built with error feedback enabled.  Rides evict/rematerialize
    /// and checkpoints exactly like the Adam moments.
    pub ef: Vec<f32>,
    /// Round stamp for LRU eviction.
    last_used: u64,
    /// False iff the LoRA/head provably equal the pool baseline (set
    /// right after an aggregation, cleared on the next acquire).
    dirty: bool,
}

/// Compact cold-client payload: flat f32 segments in a fixed layout
/// (LORA_KEYS order; Adam m then v).  `None` LoRA/head segments mean
/// "equal to the pool baseline" — the post-aggregation compaction.
#[derive(Debug)]
struct Spill {
    step_c: u64,
    step_s: u64,
    adam_c: Vec<f32>,
    adam_s: Vec<f32>,
    lora_c: Option<Vec<f32>>,
    lora_s: Option<Vec<f32>>,
    head: Option<Vec<f32>>,
    /// Transport error-feedback residual — unlike the LoRA/head
    /// segments it is never derivable from the baseline, so it always
    /// rides the spill (empty when error feedback is off).
    ef: Vec<f32>,
    iter_indices: Vec<usize>,
    iter_cursor: usize,
    iter_rng: u64,
}

impl Spill {
    fn payload_bytes(&self) -> u64 {
        let f32s = self.adam_c.len()
            + self.adam_s.len()
            + self.ef.len()
            + self.lora_c.as_ref().map_or(0, Vec::len)
            + self.lora_s.as_ref().map_or(0, Vec::len)
            + self.head.as_ref().map_or(0, Vec::len);
        (f32s * 4 + self.iter_indices.len() * std::mem::size_of::<usize>()) as u64
    }
}

#[derive(Debug)]
enum Entry {
    /// Never participated: state is derivable from the baseline.
    Fresh,
    /// Resident at `slots[idx]`.
    Resident(usize),
    /// Materialized once, currently evicted.
    Spilled(Box<Spill>),
}

/// The state-pool subsystem (see module docs).
#[derive(Debug)]
pub struct StatePool {
    // sflint:allow(checkpoint-coverage, model geometry is rebuilt from config at load)
    dims: ModelDims,
    cuts: Vec<usize>,
    /// 0 = eager/unbounded; otherwise residency is capped at
    /// `max(cap, round cohort)`.
    // sflint:allow(checkpoint-coverage, capacity knob is fixed at construction)
    cap: usize,
    // sflint:allow(checkpoint-coverage, derived from the experiment seed at construction)
    iter_seed_base: u64,
    /// Canonical full-depth model every non-materialized client equals:
    /// the initial LoRA before round 1, the last aggregate after.
    baseline: AdapterSet,
    baseline_head: HeadState,
    entries: Vec<Entry>,
    slots: Vec<ClientSlot>,
    /// Recycled buffer sets (reshaped in place on reuse).
    // sflint:allow(checkpoint-coverage, free list is a perf cache; empty on restore is correct)
    free: Vec<(ClientState, ServerState)>,
    // sflint:allow(checkpoint-coverage, scratch buffer, rebuilt on first use)
    shard_scratch: Vec<usize>,
    // sflint:allow(checkpoint-coverage, re-stamped by begin_round before any use)
    round: u64,
    // sflint:allow(checkpoint-coverage, re-stamped by begin_round before any use)
    round_cap: usize,
    // sflint:allow(checkpoint-coverage, telemetry counter, not run state)
    hits: u64,
    // sflint:allow(checkpoint-coverage, telemetry counter, not run state)
    misses: u64,
    // sflint:allow(checkpoint-coverage, telemetry counter, not run state)
    evictions: u64,
    // sflint:allow(checkpoint-coverage, telemetry counter, not run state)
    spilled_count: usize,
    // sflint:allow(checkpoint-coverage, telemetry counter, not run state)
    resident_bytes: u64,
    // sflint:allow(checkpoint-coverage, telemetry counter, not run state)
    peak_resident_bytes: u64,
    // sflint:allow(checkpoint-coverage, telemetry counter, not run state)
    spill_bytes: u64,
    /// True once [`StatePool::enable_error_feedback`] ran: every slot
    /// carries a transport EF residual and checkpoints gain the
    /// per-client `scheme.c{u}.ef` keys (legacy layouts stay byte-
    /// stable when off).  Covered in save_state/load_state.
    ef_active: bool,
}

/// Resize a tensor's leading axis in place — no `HostTensor`
/// constructor runs, so recycling a buffer across cut depths never
/// counts against the allocation gates (the payload `Vec` keeps its
/// high-water capacity after the first deep materialization).
pub(crate) fn reshape_rows(t: &mut HostTensor, rows: usize) {
    if t.shape.first() == Some(&rows) {
        return;
    }
    let inner: usize = t.shape[1..].iter().product();
    t.shape[0] = rows;
    match &mut t.data {
        TensorData::F32(v) => v.resize(rows * inner, 0.0),
        TensorData::I32(v) => v.resize(rows * inner, 0),
    }
}

/// Concatenate tensors' payloads into one flat f32 vector (spill
/// encoding; layout is the iteration order).  `cap` is the exact total
/// element count — spills are built on the round hot path, so they must
/// not grow through repeated reallocation.
fn flatten<'a>(cap: usize, ts: impl Iterator<Item = &'a HostTensor>) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(cap);
    for t in ts {
        out.extend_from_slice(t.as_f32()?);
    }
    Ok(out)
}

/// Inverse of [`flatten`]: refill tensors from the flat payload.
fn unflatten<'a>(flat: &[f32], ts: impl Iterator<Item = &'a mut HostTensor>) -> Result<()> {
    let mut at = 0usize;
    for t in ts {
        let d = t.as_f32_mut()?;
        let end = at + d.len();
        if end > flat.len() {
            bail!("spill payload too short at tensor {}", t.name);
        }
        d.copy_from_slice(&flat[at..end]);
        at = end;
    }
    if at != flat.len() {
        bail!("spill payload has {} trailing values", flat.len() - at);
    }
    Ok(())
}

impl StatePool {
    /// Build the pool over `cuts` with the initial full-depth model as
    /// baseline.  `cap = 0` materializes every client up front (eager);
    /// otherwise the pool starts empty and fills on first participation.
    pub fn new(
        dims: &ModelDims,
        cuts: &[usize],
        full0: AdapterSet,
        head0: HeadState,
        iter_seed_base: u64,
        cap: usize,
        data: &DataPool,
    ) -> Result<Self> {
        if full0.layers != dims.layers {
            bail!("baseline has {} layers, dims say {}", full0.layers, dims.layers);
        }
        if data.clients() != cuts.len() {
            bail!("data pool has {} clients, cuts {}", data.clients(), cuts.len());
        }
        let n = cuts.len();
        let mut pool = Self {
            dims: dims.clone(),
            cuts: cuts.to_vec(),
            cap,
            iter_seed_base,
            baseline: full0,
            baseline_head: head0,
            entries: (0..n).map(|_| Entry::Fresh).collect(),
            slots: Vec::new(),
            free: Vec::new(),
            shard_scratch: Vec::new(),
            round: 0,
            round_cap: if cap == 0 { usize::MAX } else { cap },
            hits: 0,
            misses: 0,
            evictions: 0,
            spilled_count: 0,
            resident_bytes: 0,
            peak_resident_bytes: 0,
            spill_bytes: 0,
            ef_active: false,
        };
        if cap == 0 {
            for u in 0..n {
                pool.acquire(u, data)?;
            }
            // Construction is not a cache event.
            pool.hits = 0;
            pool.misses = 0;
        }
        Ok(pool)
    }

    pub fn clients(&self) -> usize {
        self.entries.len()
    }

    /// True when residency is bounded (lazy/pooled mode).
    pub fn is_pooled(&self) -> bool {
        self.cap > 0
    }

    /// Exact per-client resident state bytes.  Independent of the cut:
    /// client + server LoRA tile the full depth, and each side holds
    /// 3 copies (param + Adam m + v) plus the server head's 3 copies.
    pub fn bytes_per_client(&self) -> u64 {
        let d = &self.dims;
        let lora = 4 * d.layers * d.rank * d.hidden;
        let head = d.hidden * d.classes + d.classes;
        ((3 * lora + 3 * head) * 4) as u64
    }

    /// What the eager mode keeps resident for this fleet — the bench
    /// comparison point (exact, since eager residency is deterministic).
    pub fn eager_state_bytes(&self) -> u64 {
        self.entries.len() as u64 * self.bytes_per_client()
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            resident: self.slots.len(),
            spilled: self.spilled_count,
            resident_bytes: self.resident_bytes,
            peak_resident_bytes: self.peak_resident_bytes,
            spill_bytes: self.spill_bytes,
        }
    }

    /// Cuts of the currently resident clients (feeds the analytic
    /// memory accountant's pooled variant).
    pub fn resident_cuts(&self) -> Vec<usize> {
        self.slots.iter().map(|s| self.cuts[s.client]).collect()
    }

    /// The shared baseline adapters (the post-aggregation full model) —
    /// the reference point robust aggregation measures deltas against.
    pub fn baseline(&self) -> &AdapterSet {
        &self.baseline
    }

    /// The baseline's classifier head (paired with [`StatePool::baseline`];
    /// the async engine snapshots both per model version).
    pub fn baseline_head(&self) -> &HeadState {
        &self.baseline_head
    }

    /// Turn on transport error-feedback residuals: every slot
    /// (present and future) carries a zero-initialized flat residual
    /// over its client-half LoRA.  Called once at session construction
    /// when the transport config is active with `--error-feedback`;
    /// idempotent.
    pub fn enable_error_feedback(&mut self) {
        self.ef_active = true;
        for slot in self.slots.iter_mut() {
            if slot.ef.is_empty() {
                slot.ef.resize(slot.cs.lora.param_count(), 0.0);
            }
        }
    }

    /// The transport codec's mutable handle on a resident client's
    /// error-feedback residual (the client must have been acquired this
    /// round, so residency is an invariant, not a race).
    pub fn ef_mut(&mut self, u: usize) -> Result<&mut Vec<f32>> {
        match self.entries.get(u) {
            Some(Entry::Resident(i)) => Ok(&mut self.slots[*i].ef),
            _ => bail!("client {u} is not resident; acquire before ef_mut"),
        }
    }

    /// Zero client `u`'s error-feedback residual wherever it lives.
    ///
    /// The robust layer calls this when a client enters quarantine (its
    /// residual may hold adversarial mass the codec would re-inject
    /// into later uploads) and again on probation re-admission (the
    /// probationary updates start from a clean slate).  Fresh entries
    /// have no residual yet; no-op when error feedback is inactive.
    pub fn clear_error_feedback(&mut self, u: usize) {
        if !self.ef_active {
            return;
        }
        match self.entries.get_mut(u) {
            Some(Entry::Resident(i)) => {
                let i = *i;
                self.slots[i].ef.fill(0.0);
            }
            Some(Entry::Spilled(sp)) => sp.ef.fill(0.0),
            _ => {}
        }
    }

    /// Borrow a client's slot if (and only if) it is resident.
    pub fn resident(&self, u: usize) -> Option<&ClientSlot> {
        match self.entries.get(u) {
            Some(Entry::Resident(i)) => Some(&self.slots[*i]),
            _ => None,
        }
    }

    /// Start a round: stamp the LRU clock and shrink residency to
    /// `max(cap, cohort)` (the cohort bound guarantees a round's
    /// participants are never evicted mid-round).
    pub fn begin_round(&mut self, round: u64, cohort: usize) -> Result<()> {
        self.round = round;
        if self.cap == 0 {
            return Ok(());
        }
        self.round_cap = self.cap.max(cohort);
        while self.slots.len() > self.round_cap {
            let Some(i) = self.coldest() else { break };
            self.evict_slot(i)?;
        }
        Ok(())
    }

    /// Ensure client `u` is resident (materializing or un-spilling as
    /// needed, evicting the coldest resident when at capacity) and
    /// return its slot for in-place training.
    pub fn acquire(&mut self, u: usize, data: &DataPool) -> Result<&mut ClientSlot> {
        match self.entries[u] {
            Entry::Resident(_) => self.hits += 1,
            Entry::Fresh => {
                self.make_room()?;
                self.materialize_fresh(u, data)?;
            }
            Entry::Spilled(_) => {
                self.make_room()?;
                self.materialize_spilled(u)?;
            }
        }
        let Entry::Resident(i) = self.entries[u] else {
            unreachable!("client {u} must be resident after acquire");
        };
        let round = self.round;
        let slot = &mut self.slots[i];
        slot.last_used = round;
        slot.dirty = true;
        Ok(slot)
    }

    fn coldest(&self) -> Option<usize> {
        (0..self.slots.len()).min_by_key(|&i| self.slots[i].last_used)
    }

    fn make_room(&mut self) -> Result<()> {
        while self.slots.len() >= self.round_cap {
            let Some(i) = self.coldest() else { break };
            self.evict_slot(i)?;
        }
        Ok(())
    }

    /// Take a recycled buffer set (reshaped for cut `k`) or allocate a
    /// fresh one — the only `HostTensor`-allocating path in the pool,
    /// hit at most once per watermark slot.
    fn buffers_for(&mut self, k: usize) -> (ClientState, ServerState) {
        let layers = self.dims.layers;
        if let Some((mut cs, mut ss)) = self.free.pop() {
            for t in cs.lora.tensors.iter_mut() {
                reshape_rows(t, k);
            }
            cs.lora.layers = k;
            for t in cs.adam.m.iter_mut().chain(cs.adam.v.iter_mut()) {
                reshape_rows(t, k);
            }
            for t in ss.lora.tensors.iter_mut() {
                reshape_rows(t, layers - k);
            }
            ss.lora.layers = layers - k;
            // Server Adam: the first 4 moments mirror the LoRA stack;
            // the head-shaped tail (w, b) is cut-independent.
            for t in ss.adam.m.iter_mut().take(4).chain(ss.adam.v.iter_mut().take(4)) {
                reshape_rows(t, layers - k);
            }
            return (cs, ss);
        }
        self.fresh_buffers(k)
    }

    /// Allocate a brand-new buffer set for cut `k` (pool construction,
    /// watermark growth, and checkpoint export).
    fn fresh_buffers(&self, k: usize) -> (ClientState, ServerState) {
        let c_lora = AdapterSet::zeros(&self.dims, k);
        let s_lora = AdapterSet::zeros(&self.dims, self.dims.layers - k);
        let head = HeadState {
            w: HostTensor::zeros(
                self.baseline_head.w.name.clone(),
                self.baseline_head.w.shape.clone(),
            ),
            b: HostTensor::zeros(
                self.baseline_head.b.name.clone(),
                self.baseline_head.b.shape.clone(),
            ),
        };
        (ClientState::fresh(c_lora), ServerState::fresh(s_lora, head))
    }

    /// Decode a spill's payloads into pre-shaped state buffers — the
    /// single home of the spill layout's read side, shared by
    /// rematerialization and checkpoint export.  Returns the dirty
    /// flag (the spill carried its own LoRA/head rather than the
    /// baseline's).
    fn fill_from_spill(
        &self,
        u: usize,
        sp: &Spill,
        cs: &mut ClientState,
        ss: &mut ServerState,
    ) -> Result<bool> {
        let k = self.cuts[u];
        let dirty = match (&sp.lora_c, &sp.lora_s) {
            (Some(lc), Some(ls)) => {
                unflatten(lc, cs.lora.tensors.iter_mut())?;
                unflatten(ls, ss.lora.tensors.iter_mut())?;
                true
            }
            (None, None) => {
                self.baseline.split_into(k, &mut cs.lora, &mut ss.lora)?;
                false
            }
            _ => bail!("client {u} spill has mismatched LoRA halves"),
        };
        match &sp.head {
            Some(h) => {
                let hw = ss.head.w.numel();
                if h.len() != hw + ss.head.b.numel() {
                    bail!("client {u} spill head payload has wrong length");
                }
                ss.head.w.as_f32_mut()?.copy_from_slice(&h[..hw]);
                ss.head.b.as_f32_mut()?.copy_from_slice(&h[hw..]);
            }
            None => {
                ops::copy_from(&mut ss.head.w, &self.baseline_head.w)?;
                ops::copy_from(&mut ss.head.b, &self.baseline_head.b)?;
            }
        }
        unflatten(&sp.adam_c, cs.adam.m.iter_mut().chain(cs.adam.v.iter_mut()))?;
        unflatten(&sp.adam_s, ss.adam.m.iter_mut().chain(ss.adam.v.iter_mut()))?;
        cs.step = sp.step_c;
        ss.step = sp.step_s;
        Ok(dirty)
    }

    fn push_slot(
        &mut self,
        u: usize,
        cs: ClientState,
        ss: ServerState,
        it: BatchIter,
        ef: Vec<f32>,
        dirty: bool,
    ) {
        let idx = self.slots.len();
        self.slots.push(ClientSlot { client: u, cs, ss, it, ef, last_used: self.round, dirty });
        self.entries[u] = Entry::Resident(idx);
        let bytes = self.bytes_per_client();
        self.resident_bytes += bytes;
        self.peak_resident_bytes = self.peak_resident_bytes.max(self.resident_bytes);
        self.misses += 1;
    }

    /// First participation: derive the state from the baseline —
    /// bit-equal to `ClientState::fresh` / `ServerState::fresh` over
    /// `baseline.split_at(k)`.
    fn materialize_fresh(&mut self, u: usize, data: &DataPool) -> Result<()> {
        let k = self.cuts[u];
        let (mut cs, mut ss) = self.buffers_for(k);
        self.baseline.split_into(k, &mut cs.lora, &mut ss.lora)?;
        for t in cs.adam.m.iter_mut().chain(cs.adam.v.iter_mut()) {
            t.as_f32_mut()?.fill(0.0);
        }
        cs.step = 0;
        ops::copy_from(&mut ss.head.w, &self.baseline_head.w)?;
        ops::copy_from(&mut ss.head.b, &self.baseline_head.b)?;
        for t in ss.adam.m.iter_mut().chain(ss.adam.v.iter_mut()) {
            t.as_f32_mut()?.fill(0.0);
        }
        ss.step = 0;
        data.shard_into(u, &mut self.shard_scratch);
        let it =
            BatchIter::new(&self.shard_scratch, self.dims.batch, self.iter_seed_base + u as u64);
        let ef = if self.ef_active { vec![0.0; cs.lora.param_count()] } else { Vec::new() };
        self.push_slot(u, cs, ss, it, ef, false);
        Ok(())
    }

    /// Reload an evicted client from its spill, bit-exactly.
    fn materialize_spilled(&mut self, u: usize) -> Result<()> {
        let Entry::Spilled(sp) = std::mem::replace(&mut self.entries[u], Entry::Fresh) else {
            bail!("client {u} is not spilled");
        };
        self.spill_bytes -= sp.payload_bytes();
        self.spilled_count -= 1;
        let k = self.cuts[u];
        let (mut cs, mut ss) = self.buffers_for(k);
        let dirty = self.fill_from_spill(u, &sp, &mut cs, &mut ss)?;
        let mut it = BatchIter::new(&[], self.dims.batch, 0);
        let sp = *sp;
        it.restore_state(sp.iter_indices, sp.iter_cursor, sp.iter_rng);
        self.push_slot(u, cs, ss, it, sp.ef, dirty);
        Ok(())
    }

    fn evict_slot(&mut self, i: usize) -> Result<()> {
        let slot = self.slots.swap_remove(i);
        if i < self.slots.len() {
            let moved = self.slots[i].client;
            self.entries[moved] = Entry::Resident(i);
        }
        let u = slot.client;
        let head_elems = slot.ss.head.w.numel() + slot.ss.head.b.numel();
        let (lora_c, lora_s, head) = if slot.dirty {
            (
                Some(flatten(slot.cs.lora.param_count(), slot.cs.lora.tensors.iter())?),
                Some(flatten(slot.ss.lora.param_count(), slot.ss.lora.tensors.iter())?),
                Some(flatten(head_elems, [&slot.ss.head.w, &slot.ss.head.b].into_iter())?),
            )
        } else {
            (None, None, None)
        };
        let (indices, cursor, rng) = slot.it.state();
        let sp = Spill {
            step_c: slot.cs.step,
            step_s: slot.ss.step,
            adam_c: flatten(
                2 * slot.cs.lora.param_count(),
                slot.cs.adam.m.iter().chain(slot.cs.adam.v.iter()),
            )?,
            adam_s: flatten(
                2 * (slot.ss.lora.param_count() + head_elems),
                slot.ss.adam.m.iter().chain(slot.ss.adam.v.iter()),
            )?,
            lora_c,
            lora_s,
            head,
            ef: slot.ef,
            iter_indices: indices.to_vec(),
            iter_cursor: cursor,
            iter_rng: rng,
        };
        self.spill_bytes += sp.payload_bytes();
        self.spilled_count += 1;
        let bytes = self.bytes_per_client();
        self.resident_bytes -= bytes;
        self.entries[u] = Entry::Spilled(Box::new(sp));
        self.free.push((slot.cs, slot.ss));
        self.evictions += 1;
        Ok(())
    }

    /// Redistribute an aggregate (paper Alg. 1 lines 17–30) pool-wide:
    /// resident clients get it copied into their buffers (exactly like
    /// the eager path), spilled clients drop their now-stale LoRA/head
    /// segments (they equal the new baseline), fresh clients need
    /// nothing — and the baseline itself becomes the aggregate.
    pub fn apply_aggregate(&mut self, agg: &AdapterSet, head: &HeadState) -> Result<()> {
        if agg.layers != self.dims.layers {
            bail!("aggregate depth {} != model depth {}", agg.layers, self.dims.layers);
        }
        for slot in self.slots.iter_mut() {
            let k = self.cuts[slot.client];
            agg.split_into(k, &mut slot.cs.lora, &mut slot.ss.lora)?;
            ops::copy_from(&mut slot.ss.head.w, &head.w)?;
            ops::copy_from(&mut slot.ss.head.b, &head.b)?;
            slot.dirty = false;
        }
        let mut freed = 0u64;
        for e in self.entries.iter_mut() {
            if let Entry::Spilled(sp) = e {
                freed += (sp.lora_c.as_ref().map_or(0, Vec::len)
                    + sp.lora_s.as_ref().map_or(0, Vec::len)
                    + sp.head.as_ref().map_or(0, Vec::len)) as u64
                    * 4;
                sp.lora_c = None;
                sp.lora_s = None;
                sp.head = None;
            }
        }
        self.spill_bytes -= freed;
        for (d, s) in self.baseline.tensors.iter_mut().zip(agg.tensors.iter()) {
            ops::copy_from(d, s)?;
        }
        ops::copy_from(&mut self.baseline_head.w, &head.w)?;
        ops::copy_from(&mut self.baseline_head.b, &head.b)?;
        Ok(())
    }

    /// [`StatePool::apply_aggregate`] with per-client protection for the
    /// async engine: a client with `protect[u]` set keeps its current
    /// trained state — its resident buffers are not overwritten and its
    /// spill payload is not dropped — while the shared baseline still
    /// becomes the aggregate.  In-flight clients trained at dispatch
    /// against an older baseline; their undelivered updates must survive
    /// until their own completion merges them.  An all-false mask is
    /// behaviorally identical to [`StatePool::apply_aggregate`].
    pub fn apply_aggregate_protected(
        &mut self,
        agg: &AdapterSet,
        head: &HeadState,
        protect: &[bool],
    ) -> Result<()> {
        if agg.layers != self.dims.layers {
            bail!("aggregate depth {} != model depth {}", agg.layers, self.dims.layers);
        }
        if protect.len() != self.entries.len() {
            bail!(
                "protection mask covers {} clients, pool has {}",
                protect.len(),
                self.entries.len()
            );
        }
        for slot in self.slots.iter_mut() {
            if protect[slot.client] {
                continue;
            }
            let k = self.cuts[slot.client];
            agg.split_into(k, &mut slot.cs.lora, &mut slot.ss.lora)?;
            ops::copy_from(&mut slot.ss.head.w, &head.w)?;
            ops::copy_from(&mut slot.ss.head.b, &head.b)?;
            slot.dirty = false;
        }
        let mut freed = 0u64;
        for (u, e) in self.entries.iter_mut().enumerate() {
            if protect[u] {
                continue;
            }
            if let Entry::Spilled(sp) = e {
                freed += (sp.lora_c.as_ref().map_or(0, Vec::len)
                    + sp.lora_s.as_ref().map_or(0, Vec::len)
                    + sp.head.as_ref().map_or(0, Vec::len)) as u64
                    * 4;
                sp.lora_c = None;
                sp.lora_s = None;
                sp.head = None;
            }
        }
        self.spill_bytes -= freed;
        for (d, s) in self.baseline.tensors.iter_mut().zip(agg.tensors.iter()) {
            ops::copy_from(d, s)?;
        }
        ops::copy_from(&mut self.baseline_head.w, &head.w)?;
        ops::copy_from(&mut self.baseline_head.b, &head.b)?;
        Ok(())
    }

    /// Data-weighted global model over the *whole* fleet (paper
    /// eqs. 5–8), written into caller scratch.  Bit-identical to the
    /// eager `fedavg_joined_into` + `weighted_sum_into` path: clients
    /// accumulate in id order with the same per-element operations,
    /// whether their tensors live in resident buffers, spill payloads,
    /// or the shared baseline.
    pub fn global_model_into(
        &self,
        data: &DataPool,
        agg: &mut AdapterSet,
        head_out: &mut HeadState,
    ) -> Result<()> {
        let n = self.entries.len();
        if agg.layers != self.dims.layers {
            bail!("global-model scratch depth {} != {}", agg.layers, self.dims.layers);
        }
        let total: f64 = (0..n).map(|u| data.weight(u) as f64).sum();
        if (total - 1.0).abs() > 1e-4 {
            bail!("aggregation weights must sum to 1, got {total}");
        }
        for t in agg.tensors.iter_mut() {
            t.as_f32_mut()?.fill(0.0);
        }
        let rm = self.dims.rank * self.dims.hidden;
        for u in 0..n {
            let w = data.weight(u);
            let k = self.cuts[u];
            let s_layers = self.dims.layers - k;
            for i in 0..4 {
                let split = k * rm;
                let d = agg.tensors[i].as_f32_mut()?;
                match &self.entries[u] {
                    Entry::Resident(s) => {
                        let slot = &self.slots[*s];
                        ops::axpy_into(w, slot.cs.lora.tensors[i].as_f32()?, &mut d[..split])?;
                        ops::axpy_into(w, slot.ss.lora.tensors[i].as_f32()?, &mut d[split..])?;
                    }
                    Entry::Spilled(sp) if sp.lora_c.is_some() => {
                        let lc = sp.lora_c.as_ref().ok_or_else(|| {
                            anyhow::anyhow!("client {u} spill lost its LoRA client half")
                        })?;
                        let ls = sp.lora_s.as_ref().ok_or_else(|| {
                            anyhow::anyhow!("client {u} spill has mismatched LoRA halves")
                        })?;
                        ops::axpy_into(w, &lc[i * k * rm..(i + 1) * k * rm], &mut d[..split])?;
                        ops::axpy_into(
                            w,
                            &ls[i * s_layers * rm..(i + 1) * s_layers * rm],
                            &mut d[split..],
                        )?;
                    }
                    _ => {
                        let b = self.baseline.tensors[i].as_f32()?;
                        ops::axpy_into(w, &b[..split], &mut d[..split])?;
                        ops::axpy_into(w, &b[split..], &mut d[split..])?;
                    }
                }
            }
        }
        let hw = self.baseline_head.w.numel();
        let mut ws: Vec<(f32, &[f32])> = Vec::with_capacity(n);
        let mut bs: Vec<(f32, &[f32])> = Vec::with_capacity(n);
        for u in 0..n {
            let w = data.weight(u);
            match &self.entries[u] {
                Entry::Resident(s) => {
                    let slot = &self.slots[*s];
                    ws.push((w, slot.ss.head.w.as_f32()?));
                    bs.push((w, slot.ss.head.b.as_f32()?));
                }
                Entry::Spilled(sp) if sp.head.is_some() => {
                    let h = sp.head.as_ref().ok_or_else(|| {
                        anyhow::anyhow!("client {u} spill lost its head snapshot")
                    })?;
                    ws.push((w, &h[..hw]));
                    bs.push((w, &h[hw..]));
                }
                _ => {
                    ws.push((w, self.baseline_head.w.as_f32()?));
                    bs.push((w, self.baseline_head.b.as_f32()?));
                }
            }
        }
        ops::weighted_sum_slices_into(&ws, head_out.w.as_f32_mut()?)?;
        ops::weighted_sum_slices_into(&bs, head_out.b.as_f32_mut()?)?;
        Ok(())
    }

    /// Sparse serialization: the baseline plus only the *materialized*
    /// clients (resident or spilled) under the same per-client key
    /// scheme the dense checkpoints used.
    pub fn save_state(&self, out: &mut Vec<(String, HostTensor)>) -> Result<()> {
        save_adapters(out, "scheme.pool.base.lora", &self.baseline);
        out.push(("scheme.pool.base.head.w".into(), self.baseline_head.w.clone()));
        out.push(("scheme.pool.base.head.b".into(), self.baseline_head.b.clone()));
        let ids: Vec<i32> = (0..self.entries.len())
            .filter(|&u| !matches!(self.entries[u], Entry::Fresh))
            .map(|u| u as i32)
            .collect();
        let nm = ids.len();
        out.push((
            "scheme.pool.materialized".into(),
            HostTensor::i32("scheme.pool.materialized", vec![nm], ids.clone()),
        ));
        for &id in &ids {
            let u = id as usize;
            match &self.entries[u] {
                Entry::Resident(s) => {
                    let slot = &self.slots[*s];
                    save_adapters(out, &format!("scheme.c{u}.lora"), &slot.cs.lora);
                    save_adam(out, &format!("scheme.c{u}.adam"), &slot.cs.adam);
                    out.push((format!("scheme.c{u}.step"), encode_u64s("step", &[slot.cs.step])));
                    save_adapters(out, &format!("scheme.s{u}.lora"), &slot.ss.lora);
                    out.push((format!("scheme.s{u}.head.w"), slot.ss.head.w.clone()));
                    out.push((format!("scheme.s{u}.head.b"), slot.ss.head.b.clone()));
                    save_adam(out, &format!("scheme.s{u}.adam"), &slot.ss.adam);
                    out.push((format!("scheme.s{u}.step"), encode_u64s("step", &[slot.ss.step])));
                    let (indices, cursor, rng) = slot.it.state();
                    save_iter_state(out, u, indices, cursor, rng);
                    if self.ef_active {
                        out.push((
                            format!("scheme.c{u}.ef"),
                            HostTensor::f32("ef", vec![slot.ef.len()], slot.ef.clone()),
                        ));
                    }
                }
                Entry::Spilled(sp) => self.export_spill(u, sp, out)?,
                Entry::Fresh => unreachable!("fresh entries are filtered above"),
            }
        }
        Ok(())
    }

    /// Rehydrate a spilled client into ordinary named tensors for the
    /// checkpoint writer (allocation here is fine — this is not the
    /// round hot path; the decode itself is shared with
    /// [`StatePool::materialize_spilled`] via `fill_from_spill`).
    fn export_spill(
        &self,
        u: usize,
        sp: &Spill,
        out: &mut Vec<(String, HostTensor)>,
    ) -> Result<()> {
        let k = self.cuts[u];
        let (mut cs, mut ss) = self.fresh_buffers(k);
        self.fill_from_spill(u, sp, &mut cs, &mut ss)?;
        save_adapters(out, &format!("scheme.c{u}.lora"), &cs.lora);
        save_adam(out, &format!("scheme.c{u}.adam"), &cs.adam);
        out.push((format!("scheme.c{u}.step"), encode_u64s("step", &[cs.step])));
        save_adapters(out, &format!("scheme.s{u}.lora"), &ss.lora);
        out.push((format!("scheme.s{u}.head.w"), ss.head.w.clone()));
        out.push((format!("scheme.s{u}.head.b"), ss.head.b.clone()));
        save_adam(out, &format!("scheme.s{u}.adam"), &ss.adam);
        out.push((format!("scheme.s{u}.step"), encode_u64s("step", &[ss.step])));
        save_iter_state(out, u, &sp.iter_indices, sp.iter_cursor, sp.iter_rng);
        if self.ef_active {
            out.push((
                format!("scheme.c{u}.ef"),
                HostTensor::f32("ef", vec![sp.ef.len()], sp.ef.clone()),
            ));
        }
        Ok(())
    }

    /// Restore a [`StatePool::save_state`] checkpoint into a freshly
    /// constructed pool (the only supported call pattern —
    /// `Session::resume` builds the session anew first).  Clients
    /// absent from the materialized list stay derivable from the
    /// restored baseline; listed clients stream through the normal
    /// acquire/evict machinery, so a pooled resume respects the
    /// residency cap from its first round.
    pub fn load_state(&mut self, store: &ParamStore, data: &DataPool) -> Result<()> {
        load_adapters(store, "scheme.pool.base.lora", &mut self.baseline)?;
        load_tensor_into(store, "scheme.pool.base.head.w", &mut self.baseline_head.w)?;
        load_tensor_into(store, "scheme.pool.base.head.b", &mut self.baseline_head.b)?;
        let raw = store.get("scheme.pool.materialized")?.as_i32()?.to_vec();
        let n = self.entries.len();
        let mut listed = vec![false; n];
        for &id in &raw {
            if id < 0 || id as usize >= n {
                bail!("checkpoint lists materialized client {id}, fleet has {n}");
            }
            listed[id as usize] = true;
        }
        // Eager mode materialized everyone from the *initial* baseline
        // at construction; unlisted residents must be re-derived from
        // the checkpointed baseline (their Adam/steps/iterators are
        // still pristine).
        for slot in self.slots.iter_mut() {
            if listed[slot.client] {
                continue;
            }
            let k = self.cuts[slot.client];
            self.baseline.split_into(k, &mut slot.cs.lora, &mut slot.ss.lora)?;
            ops::copy_from(&mut slot.ss.head.w, &self.baseline_head.w)?;
            ops::copy_from(&mut slot.ss.head.b, &self.baseline_head.b)?;
            slot.dirty = false;
        }
        let ef_active = self.ef_active;
        for &id in &raw {
            let u = id as usize;
            let slot = self.acquire(u, data)?;
            load_adapters(store, &format!("scheme.c{u}.lora"), &mut slot.cs.lora)?;
            load_adam(store, &format!("scheme.c{u}.adam"), &mut slot.cs.adam)?;
            load_adapters(store, &format!("scheme.s{u}.lora"), &mut slot.ss.lora)?;
            load_tensor_into(store, &format!("scheme.s{u}.head.w"), &mut slot.ss.head.w)?;
            load_tensor_into(store, &format!("scheme.s{u}.head.b"), &mut slot.ss.head.b)?;
            load_adam(store, &format!("scheme.s{u}.adam"), &mut slot.ss.adam)?;
            load_iter_state(store, u, &mut slot.it)?;
            slot.cs.step = one_u64(store, &format!("scheme.c{u}.step"))?;
            slot.ss.step = one_u64(store, &format!("scheme.s{u}.step"))?;
            if ef_active {
                let ef = store.get(&format!("scheme.c{u}.ef"))?.as_f32()?;
                let want = slot.cs.lora.param_count();
                if ef.len() != want {
                    bail!(
                        "client {u} checkpoint EF residual has {} coords, expected {want}",
                        ef.len()
                    );
                }
                slot.ef.clear();
                slot.ef.extend_from_slice(ef);
            }
            slot.dirty = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::write_sflp;
    use crate::data::{generate, CorpusSpec, DataPool};
    use crate::tensor::alloc_count;

    fn dims() -> ModelDims {
        ModelDims::mini()
    }

    fn setup(n: usize, cap: usize) -> (StatePool, DataPool) {
        let d = dims();
        let spec = CorpusSpec {
            train_size: 400,
            test_size: 40,
            ..CorpusSpec::carer_like(d.vocab, d.seq)
        };
        let ds = generate(&spec);
        let cuts: Vec<usize> = (0..n).map(|u| d.cuts[u % d.cuts.len()]).collect();
        let data = DataPool::new(&ds.train, n, 0.5, 43, d.batch);
        let full0 = AdapterSet::init(&d, d.layers, 7);
        let head0 = HeadState {
            w: HostTensor::zeros("head.w", vec![d.hidden, d.classes]),
            b: HostTensor::zeros("head.b", vec![d.classes]),
        };
        let pool = StatePool::new(&d, &cuts, full0, head0, 100, cap, &data).unwrap();
        (pool, data)
    }

    fn assert_states_equal(a: (&ClientState, &ServerState), b: (&ClientState, &ServerState)) {
        assert_eq!(a.0.lora.max_abs_diff(&b.0.lora).unwrap(), 0.0);
        assert_eq!(a.0.step, b.0.step);
        for (x, y) in a.0.adam.m.iter().chain(a.0.adam.v.iter()).zip(
            b.0.adam.m.iter().chain(b.0.adam.v.iter()),
        ) {
            assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
        }
        assert_eq!(a.1.lora.max_abs_diff(&b.1.lora).unwrap(), 0.0);
        assert_eq!(a.1.head.w.as_f32().unwrap(), b.1.head.w.as_f32().unwrap());
        assert_eq!(a.1.head.b.as_f32().unwrap(), b.1.head.b.as_f32().unwrap());
        assert_eq!(a.1.step, b.1.step);
        for (x, y) in a.1.adam.m.iter().chain(a.1.adam.v.iter()).zip(
            b.1.adam.m.iter().chain(b.1.adam.v.iter()),
        ) {
            assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
        }
    }

    #[test]
    fn lazy_materialization_is_bit_equal_to_fresh() {
        let d = dims();
        let (mut pool, data) = setup(6, 2);
        let full0 = AdapterSet::init(&d, d.layers, 7);
        let head0 = HeadState {
            w: HostTensor::zeros("head.w", vec![d.hidden, d.classes]),
            b: HostTensor::zeros("head.b", vec![d.classes]),
        };
        for u in [2usize, 5] {
            let k = d.cuts[u % d.cuts.len()];
            let (c, s) = full0.split_at(k).unwrap();
            let want = (ClientState::fresh(c), ServerState::fresh(s, head0.clone()));
            let slot = pool.acquire(u, &data).unwrap();
            assert_eq!(slot.client, u);
            assert_states_equal((&slot.cs, &slot.ss), (&want.0, &want.1));
            // The derived iterator matches the data pool's stream.
            let mut scratch = Vec::new();
            let mut want_it = data.iter_for(u, 100 + u as u64, &mut scratch);
            assert_eq!(slot.it.next_batch().to_vec(), want_it.next_batch());
        }
    }

    /// Scribble recognizable values into a slot (simulated training).
    fn scribble(slot: &mut ClientSlot, tag: f32) {
        for t in slot.cs.lora.tensors.iter_mut().chain(slot.ss.lora.tensors.iter_mut()) {
            for (j, x) in t.as_f32_mut().unwrap().iter_mut().enumerate() {
                *x = tag + j as f32 * 0.25;
            }
        }
        slot.cs.adam.m[0].as_f32_mut().unwrap().fill(tag * 2.0);
        slot.ss.adam.v[5].as_f32_mut().unwrap().fill(tag * 3.0);
        slot.ss.head.w.as_f32_mut().unwrap().fill(tag * 4.0);
        slot.cs.step = 11;
        slot.ss.step = 13;
        let _ = slot.it.next_batch();
    }

    fn clone_slot(slot: &ClientSlot) -> (ClientState, ServerState, Vec<usize>, usize, u64) {
        let (idx, cur, rng) = slot.it.state();
        (slot.cs.clone(), slot.ss.clone(), idx.to_vec(), cur, rng)
    }

    #[test]
    fn evict_and_rematerialize_roundtrips_bit_exactly() {
        let (mut pool, data) = setup(8, 1);
        pool.begin_round(1, 1).unwrap();
        scribble(pool.acquire(3, &data).unwrap(), 1.5);
        let want = clone_slot(pool.resident(3).unwrap());
        // Touching other clients at cap 1 evicts client 3 to spill.
        pool.begin_round(2, 1).unwrap();
        pool.acquire(0, &data).unwrap();
        assert!(pool.resident(3).is_none(), "client 3 must be evicted");
        assert_eq!(pool.stats().spilled, 1);
        assert!(pool.stats().spill_bytes > 0);
        pool.begin_round(3, 1).unwrap();
        let slot = pool.acquire(3, &data).unwrap();
        assert_states_equal((&slot.cs, &slot.ss), (&want.0, &want.1));
        let (idx, cur, rng) = slot.it.state();
        assert_eq!((idx.to_vec(), cur, rng), (want.2, want.3, want.4));
    }

    #[test]
    fn aggregation_compacts_spills_and_rebaselines_fresh_clients() {
        let d = dims();
        let (mut pool, data) = setup(8, 1);
        pool.begin_round(1, 1).unwrap();
        scribble(pool.acquire(3, &data).unwrap(), 2.0);
        let adam_before = pool.resident(3).unwrap().cs.adam.m[0].clone();
        pool.begin_round(2, 1).unwrap();
        pool.acquire(0, &data).unwrap(); // evict 3 (dirty spill)
        let spill_before = pool.stats().spill_bytes;

        let agg = AdapterSet::init(&d, d.layers, 99);
        let head = HeadState {
            w: HostTensor::f32(
                "head.w",
                vec![d.hidden, d.classes],
                vec![0.5; d.hidden * d.classes],
            ),
            b: HostTensor::zeros("head.b", vec![d.classes]),
        };
        pool.apply_aggregate(&agg, &head).unwrap();
        assert!(
            pool.stats().spill_bytes < spill_before,
            "post-aggregation spills must drop their LoRA/head segments"
        );
        // Rematerialized client 3: LoRA/head = aggregate, Adam/steps kept.
        pool.begin_round(3, 1).unwrap();
        let slot = pool.acquire(3, &data).unwrap();
        let k = slot.cs.lora.layers;
        let (ac, as_) = agg.split_at(k).unwrap();
        assert_eq!(slot.cs.lora.max_abs_diff(&ac).unwrap(), 0.0);
        assert_eq!(slot.ss.lora.max_abs_diff(&as_).unwrap(), 0.0);
        assert_eq!(slot.ss.head.w.as_f32().unwrap(), head.w.as_f32().unwrap());
        assert_eq!(
            slot.cs.adam.m[0].as_f32().unwrap(),
            adam_before.as_f32().unwrap(),
            "Adam moments must survive aggregation"
        );
        assert_eq!(slot.cs.step, 11);
        // A never-materialized client derives from the new baseline.
        pool.begin_round(4, 1).unwrap();
        let fresh = pool.acquire(6, &data).unwrap();
        let kf = fresh.cs.lora.layers;
        let (fc, _) = agg.split_at(kf).unwrap();
        assert_eq!(fresh.cs.lora.max_abs_diff(&fc).unwrap(), 0.0);
        assert_eq!(fresh.cs.step, 0);
    }

    #[test]
    fn protected_aggregation_preserves_inflight_clients() {
        let d = dims();
        let (mut pool, data) = setup(8, 2);
        pool.begin_round(1, 2).unwrap();
        scribble(pool.acquire(2, &data).unwrap(), 1.5);
        scribble(pool.acquire(3, &data).unwrap(), 2.0);
        let want3 = clone_slot(pool.resident(3).unwrap());
        pool.begin_round(2, 2).unwrap();
        scribble(pool.acquire(4, &data).unwrap(), 2.5);
        scribble(pool.acquire(5, &data).unwrap(), 3.0);
        let want5 = clone_slot(pool.resident(5).unwrap());
        assert_eq!(pool.stats().spilled, 2, "clients 2 and 3 must be spilled");

        let agg = AdapterSet::init(&d, d.layers, 99);
        let head = HeadState {
            w: HostTensor::f32(
                "head.w",
                vec![d.hidden, d.classes],
                vec![0.5; d.hidden * d.classes],
            ),
            b: HostTensor::zeros("head.b", vec![d.classes]),
        };
        let mut protect = vec![false; 8];
        protect[3] = true; // protected while spilled
        protect[5] = true; // protected while resident
        pool.apply_aggregate_protected(&agg, &head, &protect).unwrap();

        // The baseline still becomes the aggregate for everyone else.
        assert_eq!(pool.baseline().max_abs_diff(&agg).unwrap(), 0.0);
        assert_eq!(pool.baseline_head().w.as_f32().unwrap(), head.w.as_f32().unwrap());
        // Protected resident keeps its trained state untouched.
        let s5 = pool.resident(5).unwrap();
        assert_states_equal((&s5.cs, &s5.ss), (&want5.0, &want5.1));
        // Unprotected resident got the aggregate (Adam survives).
        let s4 = pool.resident(4).unwrap();
        let (ac, as_) = agg.split_at(s4.cs.lora.layers).unwrap();
        assert_eq!(s4.cs.lora.max_abs_diff(&ac).unwrap(), 0.0);
        assert_eq!(s4.ss.lora.max_abs_diff(&as_).unwrap(), 0.0);
        assert_eq!(s4.ss.head.w.as_f32().unwrap(), head.w.as_f32().unwrap());
        assert_eq!(s4.cs.adam.m[0].as_f32().unwrap()[0], 2.5 * 2.0);
        // Protected spill payload survived: re-acquire is bit-exact.
        pool.begin_round(3, 2).unwrap();
        let s3 = pool.acquire(3, &data).unwrap();
        assert_states_equal((&s3.cs, &s3.ss), (&want3.0, &want3.1));
        // Unprotected spill dropped its segments and rebaselines.
        let s2 = pool.acquire(2, &data).unwrap();
        let (c2, s2s) = agg.split_at(s2.cs.lora.layers).unwrap();
        assert_eq!(s2.cs.lora.max_abs_diff(&c2).unwrap(), 0.0);
        assert_eq!(s2.ss.lora.max_abs_diff(&s2s).unwrap(), 0.0);
        assert_eq!(s2.ss.head.w.as_f32().unwrap(), head.w.as_f32().unwrap());
        assert_eq!(s2.cs.adam.m[0].as_f32().unwrap()[0], 1.5 * 2.0);
    }

    #[test]
    fn steady_state_reuses_arenas_without_host_tensor_allocs() {
        let (mut pool, data) = setup(40, 4);
        let mut rng = crate::tensor::rng::Rng::new(5);
        // Warm-up with distinct cohorts so the residency watermark (and
        // the recycled-arena free list) is provably reached.
        for round in 1..=3u64 {
            pool.begin_round(round, 4).unwrap();
            for j in 0..4usize {
                let u = (round as usize - 1) * 4 + j;
                pool.acquire(u, &data).unwrap();
            }
        }
        let before = alloc_count();
        for round in 4..=12u64 {
            pool.begin_round(round, 4).unwrap();
            for _ in 0..4 {
                let u = rng.below(40);
                let slot = pool.acquire(u, &data).unwrap();
                let _ = slot.it.next_batch();
            }
        }
        assert_eq!(
            alloc_count(),
            before,
            "pooled steady state must not allocate HostTensors"
        );
        let st = pool.stats();
        assert!(st.resident <= 4);
        assert!(st.evictions > 0, "cap 4 over 40 clients must evict");
        assert_eq!(st.resident_bytes, st.resident as u64 * pool.bytes_per_client());
        assert!(st.peak_resident_bytes <= 4 * pool.bytes_per_client());
    }

    #[test]
    fn eager_mode_materializes_everyone_up_front() {
        let (pool, _) = setup(6, 0);
        let st = pool.stats();
        assert_eq!(st.resident, 6);
        assert_eq!(st.spilled, 0);
        assert_eq!(st.resident_bytes, pool.eager_state_bytes());
        assert!(!pool.is_pooled());
    }

    #[test]
    fn global_model_matches_across_entry_states() {
        // The pooled global model (resident + spilled + fresh mix) must
        // bit-match an all-resident (eager) pool holding identical
        // per-client state.
        let d = dims();
        let (mut pooled, data) = setup(6, 1);
        let (mut eager, data_e) = setup(6, 0);
        // Train clients 0 and 1 in the pooled world; mirror into eager.
        for (u, tag) in [(0usize, 3.0f32), (1, 4.5)] {
            pooled.begin_round(u as u64 + 1, 1).unwrap();
            scribble(pooled.acquire(u, &data).unwrap(), tag);
            scribble(eager.acquire(u, &data_e).unwrap(), tag);
        }
        // Client 0 is now spilled (cap 1), client 1 resident, 2..6 fresh.
        assert!(pooled.resident(0).is_none());
        assert!(pooled.resident(1).is_some());
        let mut agg_a = AdapterSet::zeros(&d, d.layers);
        let mut agg_b = AdapterSet::zeros(&d, d.layers);
        let mk_head = || HeadState {
            w: HostTensor::zeros("head.w", vec![d.hidden, d.classes]),
            b: HostTensor::zeros("head.b", vec![d.classes]),
        };
        let mut ha = mk_head();
        let mut hb = mk_head();
        pooled.global_model_into(&data, &mut agg_a, &mut ha).unwrap();
        eager.global_model_into(&data_e, &mut agg_b, &mut hb).unwrap();
        for (x, y) in agg_a.tensors.iter().zip(agg_b.tensors.iter()) {
            assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
        }
        assert_eq!(ha.w.as_f32().unwrap(), hb.w.as_f32().unwrap());
        assert_eq!(ha.b.as_f32().unwrap(), hb.b.as_f32().unwrap());
    }

    #[test]
    fn sparse_save_restore_roundtrips_materialized_and_fresh() {
        let (mut pool, data) = setup(10, 2);
        pool.begin_round(1, 2).unwrap();
        scribble(pool.acquire(4, &data).unwrap(), 6.0);
        scribble(pool.acquire(7, &data).unwrap(), 7.0);
        pool.begin_round(2, 2).unwrap();
        scribble(pool.acquire(1, &data).unwrap(), 8.0); // evicts one of 4/7
        pool.begin_round(3, 2).unwrap();
        pool.acquire(4, &data).unwrap();
        let want4 = clone_slot(pool.resident(4).unwrap());
        let mut named: Vec<(String, HostTensor)> = Vec::new();
        pool.save_state(&mut named).unwrap();
        // Only 3 clients are serialized (sparse), plus baseline + list.
        let listed = named
            .iter()
            .find(|(n, _)| n == "scheme.pool.materialized")
            .map(|(_, t)| t.as_i32().unwrap().to_vec())
            .unwrap();
        assert_eq!(listed, vec![1, 4, 7]);
        assert!(!named.iter().any(|(n, _)| n.starts_with("scheme.c0.")));
        let dir = std::env::temp_dir().join("sfl_pool_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pool.sflp");
        let borrowed: Vec<(&str, &HostTensor)> =
            named.iter().map(|(n, t)| (n.as_str(), t)).collect();
        write_sflp(&path, &borrowed).unwrap();

        let (mut back, data_b) = setup(10, 2);
        let store = ParamStore::load(&path).unwrap();
        back.load_state(&store, &data_b).unwrap();
        let slot = back.acquire(4, &data_b).unwrap();
        assert_states_equal((&slot.cs, &slot.ss), (&want4.0, &want4.1));
        let (idx, cur, rng) = slot.it.state();
        assert_eq!((idx.to_vec(), cur, rng), (want4.2, want4.3, want4.4));
        // Fresh clients stay fresh after resume; exactly the 3 listed
        // clients are materialized.
        assert!(back.resident(0).is_none());
        assert_eq!(back.stats().resident + back.stats().spilled, 3);
    }

    #[test]
    fn error_feedback_residuals_ride_spill_and_checkpoint() {
        let (mut pool, data) = setup(8, 1);
        pool.enable_error_feedback();
        pool.begin_round(1, 1).unwrap();
        let slot = pool.acquire(3, &data).unwrap();
        let n = slot.cs.lora.param_count();
        assert_eq!(slot.ef.len(), n, "EF residual sized on materialization");
        for (j, r) in slot.ef.iter_mut().enumerate() {
            *r = j as f32 * 0.125 - 1.0;
        }
        let want: Vec<f32> = pool.resident(3).unwrap().ef.clone();
        // Evict → spill carries the residual → reload is bit-exact.
        pool.begin_round(2, 1).unwrap();
        pool.acquire(0, &data).unwrap();
        assert!(pool.resident(3).is_none());
        pool.begin_round(3, 1).unwrap();
        assert_eq!(pool.acquire(3, &data).unwrap().ef, want);
        // Checkpoint carries scheme.c{u}.ef and restores bit-exactly
        // into an EF-enabled pool.
        let mut named: Vec<(String, HostTensor)> = Vec::new();
        pool.save_state(&mut named).unwrap();
        assert!(named.iter().any(|(k, _)| k == "scheme.c3.ef"));
        let dir = std::env::temp_dir().join("sfl_pool_ef_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pool.sflp");
        let borrowed: Vec<(&str, &HostTensor)> =
            named.iter().map(|(n, t)| (n.as_str(), t)).collect();
        write_sflp(&path, &borrowed).unwrap();
        let (mut back, data_b) = setup(8, 1);
        back.enable_error_feedback();
        let store = ParamStore::load(&path).unwrap();
        back.load_state(&store, &data_b).unwrap();
        assert_eq!(back.acquire(3, &data_b).unwrap().ef, want);
        // With EF off the legacy checkpoint layout is untouched.
        let (mut plain, data_p) = setup(8, 1);
        plain.begin_round(1, 1).unwrap();
        plain.acquire(3, &data_p).unwrap();
        let mut legacy: Vec<(String, HostTensor)> = Vec::new();
        plain.save_state(&mut legacy).unwrap();
        assert!(!legacy.iter().any(|(k, _)| k.ends_with(".ef")));
    }

    #[test]
    fn clear_error_feedback_zeros_resident_and_spilled() {
        let (mut pool, data) = setup(8, 1);
        pool.enable_error_feedback();
        pool.begin_round(1, 1).unwrap();
        let slot = pool.acquire(3, &data).unwrap();
        for r in slot.ef.iter_mut() {
            *r = 0.5;
        }
        // Resident: cleared in place.
        pool.clear_error_feedback(3);
        assert!(pool.resident(3).unwrap().ef.iter().all(|&r| r == 0.0));
        // Spilled: refill, evict, clear, reload — still zero.
        for r in pool.acquire(3, &data).unwrap().ef.iter_mut() {
            *r = -2.0;
        }
        pool.begin_round(2, 1).unwrap();
        pool.acquire(0, &data).unwrap();
        assert!(pool.resident(3).is_none());
        pool.clear_error_feedback(3);
        pool.begin_round(3, 1).unwrap();
        assert!(pool.acquire(3, &data).unwrap().ef.iter().all(|&r| r == 0.0));
        // Fresh entries and EF-off pools are no-ops (must not panic).
        pool.clear_error_feedback(7);
        let (mut plain, data_p) = setup(4, 1);
        plain.begin_round(1, 1).unwrap();
        plain.acquire(2, &data_p).unwrap();
        plain.clear_error_feedback(2);
        assert!(plain.resident(2).unwrap().ef.is_empty());
    }

    #[test]
    fn pooled_peak_is_tiny_versus_eager() {
        let (mut pool, data) = setup(64, 2);
        let mut rng = crate::tensor::rng::Rng::new(9);
        for round in 1..=8u64 {
            pool.begin_round(round, 2).unwrap();
            for _ in 0..2 {
                pool.acquire(rng.below(64), &data).unwrap();
            }
        }
        let st = pool.stats();
        assert!(
            st.peak_resident_bytes * 16 <= pool.eager_state_bytes(),
            "peak {} vs eager {}",
            st.peak_resident_bytes,
            pool.eager_state_bytes()
        );
    }
}
