//! # sfl — Memory-Efficient Split Federated Learning for LLM Fine-Tuning
//!
//! A reproduction of *"Memory-Efficient Split Federated Learning for LLM
//! Fine-Tuning on Heterogeneous Mobile Devices"* (Chen, Li, Ji, Wu —
//! CS.DC 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the coordinator: heterogeneous split
//!   assignment, parallel client / sequential server orchestration
//!   (Alg. 1), training-order scheduling (Alg. 2), LoRA aggregation
//!   (eqs. 5–9), timing + memory models (eqs. 10–12, Table I).
//! - **L2 (python/compile/model.py)** — the BERT-like encoder fwd/bwd in
//!   JAX, AOT-lowered to HLO text once; never on the training path.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the LoRA
//!   projection hot-spot, layernorm, and attention.
//!
//! The runtime layer loads the AOT artifacts via the PJRT C API (`xla`
//! crate) and executes them from the rust coordinator; python is only a
//! build-time dependency (`make artifacts`).

// Panic discipline (mirrors sflint rule R4): library code must
// propagate errors, never unwrap.  Tests are exempt; modules that print
// by design (telemetry jsonl/stdout sinks, the bench harness) carry a
// scoped `allow` at their declaration.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]
#![deny(clippy::dbg_macro, clippy::print_stdout)]

pub mod channel;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod devices;
pub mod events;
pub mod faults;
pub mod fleet;
pub mod lint;
pub mod lora;
pub mod metrics;
pub mod model;
pub mod net;
pub mod pool;
pub mod runtime;
pub mod simclock;
// The telemetry sinks write the round log to stdout by design.
#[allow(clippy::print_stdout)]
pub mod telemetry;
pub mod tensor;
pub mod trace;
pub mod transport;
pub mod util;
