//! Minimal CLI argument parser: `prog [--flag value]... subcommand
//! [--flag value]...`.  Flags may appear before or after the subcommand;
//! `--flag=value` and boolean `--flag` forms are both accepted.

use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    /// Positional (non-flag) arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap_or_default();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn parse() -> Result<Self> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(t) => Ok(Some(t)),
                Err(e) => bail!("invalid value {v:?} for --{key}: {e}"),
            },
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("--config small table1 --max-rounds 30");
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.get("config"), Some("small"));
        assert_eq!(a.get_parse::<usize>("max-rounds").unwrap(), Some(30));
    }

    #[test]
    fn equals_form_and_bool_flags() {
        let a = parse("run --scheme=sl --quiet");
        assert_eq!(a.get("scheme"), Some("sl"));
        assert!(a.has("quiet"));
        assert_eq!(a.get("quiet"), Some("true"));
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse("run extra1 extra2");
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn bad_parse_reports_flag_name() {
        let a = parse("--n notanumber x");
        let err = a.get_parse::<usize>("n").unwrap_err().to_string();
        assert!(err.contains("--n"), "{err}");
    }

    #[test]
    fn seed_and_dropout_flags_parse() {
        // The failure-injection flags the CLI plumbs into TrainConfig.
        let a = parse("--seed 7 --dropout 0.25 run --scheme ours");
        assert_eq!(a.get_parse::<u64>("seed").unwrap(), Some(7));
        assert_eq!(a.get_parse::<f64>("dropout").unwrap(), Some(0.25));
        assert_eq!(a.subcommand.as_deref(), Some("run"));
    }

    #[test]
    fn missing_flag_is_none_and_default_works() {
        let a = parse("run");
        assert_eq!(a.get("nope"), None);
        assert_eq!(a.get_or("nope", "dflt"), "dflt");
    }
}
