//! In-tree substrates that would normally come from crates.io — this
//! workspace builds fully offline, so the CLI parser, the sectioned
//! key=value config format, the micro-bench harness, and the
//! property-testing runner are implemented here from scratch.

pub mod args;
// The micro-bench harness prints its report table to stdout by design.
#[allow(clippy::print_stdout)]
pub mod bench;
pub mod kv;
pub mod propcheck;
