//! In-tree substrates that would normally come from crates.io — this
//! workspace builds fully offline, so the CLI parser, the sectioned
//! key=value config format, the micro-bench harness, and the
//! property-testing runner are implemented here from scratch.

pub mod args;
// The micro-bench harness prints its report table to stdout by design.
#[allow(clippy::print_stdout)]
pub mod bench;
pub mod kv;
pub mod propcheck;

/// FNV-1a over raw bytes — the repo's stable content fingerprint.
///
/// Shared by trace replay (detecting a replay file changing between
/// checkpoint and resume) and the transport codec (payload integrity
/// verified server-side before merge).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod fnv1a_tests {
    use super::fnv1a;

    #[test]
    fn fnv1a_known_vectors() {
        // Offset basis: the hash of the empty input.
        assert_eq!(fnv1a(b""), 0xCBF2_9CE4_8422_2325);
        // Reference vectors from the FNV spec (fnv1a-64).
        assert_eq!(fnv1a(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a(b"foobar"), 0x85944171F73967E8);
    }

    #[test]
    fn fnv1a_is_content_sensitive() {
        assert_ne!(fnv1a(b"round=1"), fnv1a(b"round=2"));
        assert_ne!(fnv1a(b"ab"), fnv1a(b"ba"));
    }
}
