//! Micro-bench harness (criterion stand-in; this workspace builds
//! offline).  Runs warmup + timed iterations, reports min/median/mean,
//! and prints one summary line per benchmark so `cargo bench` output is
//! grep-able by the EXPERIMENTS.md tooling.

// sflint:allow(determinism, the bench harness measures wall time by design; never on the sim path)
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "bench {:<40} iters={:<5} min={:>12?} median={:>12?} mean={:>12?}",
            self.name, self.iters, self.min, self.median, self.mean
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        // sflint:allow(determinism, wall-clock timing is the point of a bench)
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let r = BenchResult { name: name.to_string(), iters, min, median, mean };
    println!("{}", r.report());
    r
}

/// Time a single (expensive) run of `f` and report it.
pub fn bench_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, Duration) {
    // sflint:allow(determinism, wall-clock timing is the point of a bench)
    let t0 = Instant::now();
    let out = f();
    let dt = t0.elapsed();
    println!("bench {name:<40} once={dt:?}");
    (out, dt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut count = 0u64;
        let r = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7); // 2 warmup + 5 timed
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.mean * 2);
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, dt) = bench_once("answer", || 42);
        assert_eq!(v, 42);
        assert!(dt.as_nanos() > 0);
    }
}
