//! Sectioned key=value config format (a TOML subset, parsed in-tree):
//!
//! ```text
//! # comment
//! scheme = ours
//! lr = 0.002
//!
//! [client]            # repeated sections accumulate into a list
//! name = Jetson Nano
//! tflops = 0.472
//! ```
//!
//! Top-level keys land in `root`; each `[section]` header starts a new
//! entry in `sections[name]`.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

#[derive(Debug, Clone, Default)]
pub struct KvTable {
    map: HashMap<String, String>,
}

impl KvTable {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing key {key:?}"))
    }

    pub fn parse<T: std::str::FromStr>(&self, key: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let v = self.require(key)?;
        v.parse::<T>().map_err(|e| anyhow::anyhow!("key {key}={v:?}: {e}"))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| anyhow::anyhow!("key {key}={v:?}: {e}")),
        }
    }

    /// Comma-separated list of T.
    pub fn parse_list<T: std::str::FromStr>(&self, key: &str) -> Result<Vec<T>>
    where
        T::Err: std::fmt::Display,
    {
        let v = self.require(key)?;
        v.split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse::<T>()
                    .map_err(|e| anyhow::anyhow!("key {key} item {s:?}: {e}"))
            })
            .collect()
    }

    pub fn insert(&mut self, key: &str, value: &str) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[derive(Debug, Clone, Default)]
pub struct KvDocument {
    pub root: KvTable,
    pub sections: Vec<(String, KvTable)>,
}

impl KvDocument {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = KvDocument::default();
        let mut current: Option<(String, KvTable)> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                if let Some(sec) = current.take() {
                    doc.sections.push(sec);
                }
                current = Some((name.trim().to_string(), KvTable::default()));
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let table = match &mut current {
                Some((_, t)) => t,
                None => &mut doc.root,
            };
            table.insert(k.trim(), v.trim().trim_matches('"'));
        }
        if let Some(sec) = current.take() {
            doc.sections.push(sec);
        }
        Ok(doc)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn sections_named<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a KvTable> {
        self.sections.iter().filter(move |(n, _)| n == name).map(|(_, t)| t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # experiment
        scheme = ours
        lr = 0.002
        cuts = 1, 2, 3

        [client]
        name = "Jetson Nano"
        tflops = 0.472

        [client]
        name = M3
        tflops = 3.533
    "#;

    #[test]
    fn parses_root_and_sections() {
        let doc = KvDocument::parse(SAMPLE).unwrap();
        assert_eq!(doc.root.get("scheme"), Some("ours"));
        assert_eq!(doc.root.parse::<f64>("lr").unwrap(), 0.002);
        assert_eq!(doc.root.parse_list::<usize>("cuts").unwrap(), vec![1, 2, 3]);
        let clients: Vec<_> = doc.sections_named("client").collect();
        assert_eq!(clients.len(), 2);
        assert_eq!(clients[0].get("name"), Some("Jetson Nano"));
        assert_eq!(clients[1].parse::<f64>("tflops").unwrap(), 3.533);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = KvDocument::parse("# only a comment\n\n  \n").unwrap();
        assert!(doc.root.is_empty());
        assert!(doc.sections.is_empty());
    }

    #[test]
    fn missing_equals_is_an_error_with_lineno() {
        let err = KvDocument::parse("a = 1\nbroken line\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn unterminated_section_rejected() {
        assert!(KvDocument::parse("[client\n").is_err());
    }

    #[test]
    fn parse_or_defaults() {
        let doc = KvDocument::parse("x = 5").unwrap();
        assert_eq!(doc.root.parse_or::<u32>("x", 1).unwrap(), 5);
        assert_eq!(doc.root.parse_or::<u32>("y", 7).unwrap(), 7);
    }
}
