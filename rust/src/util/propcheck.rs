//! Tiny property-testing runner (proptest stand-in; offline build).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from
//! `gen` and asserts `prop`; on failure it reports the failing case and
//! the draw index so the run is reproducible from the seed.

use crate::tensor::rng::Rng;

/// Run a property over `cases` generated inputs. Panics with the failing
/// input's Debug representation on the first counterexample.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            // sflint:allow(panic-discipline, panicking with the counterexample is the contract)
            panic!(
                "property {name:?} failed at case {i}/{cases} (seed {seed}):\n  input = {input:?}"
            );
        }
    }
}

/// Convenience generators.
pub mod gen {
    use crate::tensor::rng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        lo + rng.uniform() * (hi - lo)
    }

    pub fn vec_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() as f32) * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("sum-commutes", 1, 50, |r| (r.below(100), r.below(100)), |&(a, b)| {
            n += 1;
            a + b == b + a
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property \"always-small\" failed")]
    fn failing_property_panics_with_input() {
        check("always-small", 2, 100, |r| r.below(1000), |&x| x < 10);
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = crate::tensor::rng::Rng::new(3);
        for _ in 0..100 {
            let u = gen::usize_in(&mut rng, 5, 9);
            assert!((5..=9).contains(&u));
            let f = gen::f64_in(&mut rng, -1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        assert_eq!(gen::vec_f32(&mut rng, 7, 0.5).len(), 7);
    }
}
