//! Artifact-free robustness acceptance tests: the attack/defense
//! recovery gate on the closed-form `faults::testbed` world, and the
//! no-advantage guarantee for clients that lie to the timing estimator.

use sfl::coordinator::estimator::TimingEstimator;
use sfl::coordinator::scheduler::{brute_force_best, makespan, JobInfo, Scheduler};
use sfl::coordinator::timing::StepTiming;
use sfl::faults::testbed::{run, Scenario};
use sfl::faults::{AggKind, AttackKind};
use sfl::util::propcheck::{check, gen};

/// Acceptance gate (ISSUE §robust): with 20% attackers, trimmed mean
/// and norm clipping each recover ≥ 95% of the clean run's final
/// quality while plain FedAvg measurably degrades — for both the
/// non-finite corruption and the scaled-gradient attack.
#[test]
fn robust_kernels_recover_under_twenty_percent_attack() {
    let clean = run(&Scenario::default()).unwrap();
    assert!(clean.quality > 0.99, "clean run must converge, got {}", clean.quality);
    let floor = 0.95 * clean.quality;
    for attack in [AttackKind::Corrupt, AttackKind::Scale] {
        let attacked = Scenario { attack, frac: 0.2, ..Scenario::default() };
        let mean = run(&attacked).unwrap();
        assert!(
            mean.quality < 0.8,
            "{attack}: plain FedAvg should degrade under 20% attackers, got {:.4}",
            mean.quality
        );
        let trimmed = run(&Scenario {
            agg: AggKind::Trimmed,
            trim: 2,
            ..attacked.clone()
        })
        .unwrap();
        assert!(
            trimmed.quality >= floor,
            "{attack}: trimmed mean recovered only {:.4} of clean {:.4}",
            trimmed.quality,
            clean.quality
        );
        assert!(trimmed.trim_count > 0, "{attack}: trimmed mean must report trims");
        let clipped = run(&Scenario {
            agg: AggKind::Clip,
            clip_rel: 0.02,
            ..attacked
        })
        .unwrap();
        assert!(
            clipped.quality >= floor,
            "{attack}: norm clip recovered only {:.4} of clean {:.4}",
            clipped.quality,
            clean.quality
        );
        assert!(clipped.trim_count > 0, "{attack}: norm clip must report clips");
    }
}

/// The two merge-kernel-independent defenses each recover on their own
/// with the *plain* mean: the pre-merge sanitizer rejects attacker
/// updates by norm, and a full-coverage committee quarantines every
/// attacker after its first faulty round.
#[test]
fn sanitizer_and_committee_each_recover_with_plain_mean() {
    let clean = run(&Scenario::default()).unwrap();
    let floor = 0.95 * clean.quality;
    for attack in [AttackKind::Corrupt, AttackKind::Scale] {
        let sanitized = run(&Scenario {
            attack,
            frac: 0.2,
            sanitize: true,
            ..Scenario::default()
        })
        .unwrap();
        assert!(
            sanitized.quality >= floor,
            "{attack}: sanitizer recovered only {:.4}",
            sanitized.quality
        );
        assert!(sanitized.rejected > 0, "{attack}: sanitizer must reject updates");
        let verified = run(&Scenario {
            attack,
            frac: 0.2,
            verify_frac: 1.0,
            ..Scenario::default()
        })
        .unwrap();
        assert_eq!(
            verified.quarantined, 2,
            "{attack}: full-coverage committee must quarantine both attackers"
        );
        assert_eq!(verified.flagged, 2, "{attack}: each attacker flagged exactly once");
        assert!(
            verified.quality >= floor,
            "{attack}: committee recovered only {:.4}",
            verified.quality
        );
    }
}

/// A stale replay is a *mild* attack (yesterday's honest step still
/// points roughly at the optimum) — the robust kernels must not make
/// things worse than the clean floor allows.
#[test]
fn trimmed_mean_tolerates_stale_replays() {
    let clean = run(&Scenario::default()).unwrap();
    let stale = run(&Scenario {
        attack: AttackKind::Stale,
        frac: 0.2,
        agg: AggKind::Trimmed,
        trim: 2,
        ..Scenario::default()
    })
    .unwrap();
    assert!(
        stale.quality >= 0.95 * clean.quality,
        "stale replay under trimmed mean recovered only {:.4}",
        stale.quality
    );
}

/// Paper-model fleet (zero arrivals, equal server times, backward time
/// `N_c / C`): the greedy Alg. 2 order over *true* jobs is provably
/// optimal, so a client that lies to the timing estimator — by any
/// factor, over- or under-reporting — can only reorder the schedule
/// away from the optimum.  Its true makespan never beats the honest
/// fleet's: timing lies buy no advantage.
#[test]
fn prop_timing_liars_gain_no_makespan_advantage() {
    check(
        "liar-no-advantage",
        53,
        60,
        |rng| {
            let n = gen::usize_in(rng, 2, 6);
            let ts = gen::f64_in(rng, 0.5, 2.0);
            let jobs: Vec<JobInfo> = (0..n)
                .map(|i| {
                    let nc = gen::usize_in(rng, 1, 6);
                    let c = gen::f64_in(rng, 0.2, 4.0);
                    JobInfo {
                        client: i,
                        arrival: 0.0,
                        server_time: ts,
                        client_bwd_time: nc as f64 / c,
                        bwd_comm_time: 0.0,
                        n_client_adapters: nc,
                        compute_capability: c,
                    }
                })
                .collect();
            // At least one liar; lie factor covers over- and
            // under-reporting across three orders of magnitude.
            let liar = gen::usize_in(rng, 0, n - 1);
            let liars: Vec<bool> =
                (0..n).map(|u| u == liar || gen::usize_in(rng, 0, 2) == 0).collect();
            let lam = gen::f64_in(rng, 2.0, 1000.0);
            let lam = if gen::usize_in(rng, 0, 1) == 1 { 1.0 / lam } else { lam };
            (jobs, liars, lam)
        },
        |(jobs, liars, lam)| {
            let (_, best) = brute_force_best(jobs);
            let mut honest = TimingEstimator::new(jobs.len(), 0.3);
            let mut lying = TimingEstimator::new(jobs.len(), 0.3);
            for (u, j) in jobs.iter().enumerate() {
                let obs = StepTiming::from_job(j);
                honest.observe(u, &obs);
                let lie = obs.scaled(*lam);
                lying.observe(u, if liars[u] { &lie } else { &obs });
            }
            let mut hv = Vec::new();
            honest.jobs_into(jobs, &mut hv);
            let mut lv = Vec::new();
            lying.jobs_into(jobs, &mut lv);
            let honest_order = sfl::coordinator::scheduler::ProposedScheduler.order(&hv);
            let lying_order = sfl::coordinator::scheduler::ProposedScheduler.order(&lv);
            // Both makespans are evaluated on the TRUE jobs — the lie
            // only changes the order the server picks.
            let m_honest = makespan(jobs, &honest_order);
            let m_lying = makespan(jobs, &lying_order);
            m_honest <= best + 1e-9 && m_lying >= m_honest - 1e-6
        },
    );
}
