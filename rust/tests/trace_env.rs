//! Environment traces end to end on the timing model (no artifacts):
//! trace determinism and checkpoint properties, the Markov stationary
//! distribution, replay jsonl round-trips, and the non-stationary
//! regret acceptance gate.
//!
//! Acceptance (ISSUE 4): on a non-stationary (random-walk MFU)
//! 100-client fleet, estimator-driven scheduling accumulates strictly
//! less cumulative regret than the static nominal model, and a
//! checkpointed mid-trace timeline resumes with a bit-identical
//! remaining trajectory.

use sfl::coordinator::regret::{run_regret, RegretConfig};
use sfl::trace::{
    EnvTimeline, MarkovOnOff, RandomWalk, Replay, Trace, TraceKind, TraceSpec,
};

fn spec(kind: TraceKind) -> TraceSpec {
    TraceSpec {
        kind,
        seed: 5,
        mfu_sigma: 0.08,
        link_sigma: 0.05,
        revert: 0.01,
        period: 600.0,
        amp: 0.4,
        jitter: 0.05,
        mean_up: 300.0,
        mean_down: 60.0,
        obs_noise_sigma: 0.1,
        replay_path: String::new(),
    }
}

/// Acceptance gate: tracking drift online must beat ignoring it.
#[test]
fn estimator_beats_static_nominal_on_random_walk_100_client_fleet() {
    let mut rc = RegretConfig::new(spec(TraceKind::RandomWalk));
    rc.n = 100;
    rc.rounds = 120;
    let rep = run_regret(&rc).unwrap();
    assert_eq!(rep.rounds, 120);
    assert!(rep.oracle_total > 0.0);
    assert!(
        rep.estimator < rep.nominal,
        "estimator-driven cumulative regret ({:.3}s) must be strictly below the static \
         nominal model's ({:.3}s) on a drifting fleet",
        rep.estimator,
        rep.nominal
    );
    // And the drift must actually cost the static model something —
    // otherwise the gate above is vacuous.
    assert!(
        rep.nominal > 0.0,
        "random-walk drift produced no nominal-model regret ({:.6})",
        rep.nominal
    );
}

/// Any `Trace` replayed from a checkpoint resumes bit-exactly
/// (generator-level property; the timeline-level version is in
/// `trace::timeline` unit tests, the session-level version in
/// `tests/session_checkpoint.rs`).
#[test]
fn traces_resume_bit_exactly_from_checkpoint_state() {
    let mut walk = RandomWalk::new(7, 1.0, 0.1, 0.02, 0.2, 5.0);
    let mut markov = MarkovOnOff::new(7, 80.0, 30.0);
    for i in 1..=25 {
        let t = i as f64 * 4.7;
        walk.value_at(t);
        markov.value_at(t);
    }
    let mut walk_state = Vec::new();
    walk.save_state(&mut walk_state);
    let mut markov_state = Vec::new();
    markov.save_state(&mut markov_state);

    let mut walk2 = RandomWalk::new(7, 1.0, 0.1, 0.02, 0.2, 5.0);
    walk2.restore_state(&walk_state).unwrap();
    let mut markov2 = MarkovOnOff::new(7, 80.0, 30.0);
    markov2.restore_state(&markov_state).unwrap();
    for i in 26..=80 {
        let t = i as f64 * 4.7;
        assert_eq!(walk.value_at(t).to_bits(), walk2.value_at(t).to_bits(), "walk t={t}");
        assert_eq!(markov.value_at(t).to_bits(), markov2.value_at(t).to_bits(), "markov t={t}");
    }
}

/// `MarkovOnOff` long-run availability matches its stationary
/// distribution within tolerance — across parameterizations AND
/// sampling intervals.  The coarse-dt rows are the regression for the
/// naive single-flip discretization, which skews the stationary
/// distribution once round gaps approach the holding times (a
/// 100-client round's makespan easily does).
#[test]
fn markov_on_off_matches_stationary_availability() {
    for (mean_up, mean_down, dt) in [
        (300.0, 100.0, 5.0),
        (100.0, 100.0, 5.0),
        (60.0, 240.0, 5.0),
        (300.0, 100.0, 300.0), // dt == mean_up: exact CTMC probabilities required
        (300.0, 60.0, 150.0),
    ] {
        let mut m = MarkovOnOff::new(41, mean_up, mean_down);
        let expect = m.stationary_availability();
        let n = 40_000;
        let mut up = 0usize;
        for i in 1..=n {
            if m.value_at(i as f64 * dt) > 0.5 {
                up += 1;
            }
        }
        let frac = up as f64 / n as f64;
        assert!(
            (frac - expect).abs() < 0.06,
            "mean_up={mean_up} mean_down={mean_down} dt={dt}: availability {frac:.3} vs {expect:.3}"
        );
    }
}

/// `Replay` round-trips through its jsonl file format on disk.
#[test]
fn replay_file_roundtrip_preserves_the_trajectory() {
    let dir = std::env::temp_dir().join("sfl_trace_env_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.jsonl");
    let original =
        Replay::from_points(vec![(0.0, 1.0), (12.5, 0.625), (40.0, 1.75), (40.0, 1.5)]).unwrap();
    std::fs::write(&path, original.to_jsonl()).unwrap();
    let (back, hash) = Replay::load(&path).unwrap();
    assert_ne!(hash, 0);
    assert_eq!(original.points().len(), back.points().len());
    for (&(ta, va), &(tb, vb)) in original.points().iter().zip(back.points().iter()) {
        assert_eq!(ta.to_bits(), tb.to_bits());
        assert_eq!(va.to_bits(), vb.to_bits());
    }
    // Same hash for same content; different for different content.
    let (_, hash2) = Replay::load(&path).unwrap();
    assert_eq!(hash, hash2);
    std::fs::write(&path, "{\"t\": 0.0, \"v\": 2.0}\n").unwrap();
    let (_, hash3) = Replay::load(&path).unwrap();
    assert_ne!(hash, hash3);
}

/// A checkpointed mid-trace timeline resumes with a bit-identical
/// remaining trajectory — including through the exact per-round sample
/// times a session would use (irregular, makespan-driven).
#[test]
fn mid_trace_timeline_checkpoint_resumes_bit_identically() {
    for kind in [TraceKind::RandomWalk, TraceKind::Diurnal, TraceKind::Markov] {
        let s = spec(kind);
        let n = 24;
        let mut full = EnvTimeline::new(&s, n).unwrap();
        let mut first = EnvTimeline::new(&s, n).unwrap();
        // Irregular sample times, like makespan-accrued sim clocks.
        let times: Vec<f64> = (1..=40).map(|i| (i as f64) * 3.9 + (i % 5) as f64 * 0.37).collect();
        for t in &times[..15] {
            full.advance(*t);
            first.advance(*t);
        }
        let words = first.state();
        drop(first);
        // Resume path: re-synthesize from the spec, restore state.
        let mut resumed = EnvTimeline::new(&s, n).unwrap();
        resumed.restore_state(&words).unwrap();
        for t in &times[15..] {
            full.advance(*t);
            resumed.advance(*t);
            for u in 0..n {
                assert_eq!(
                    full.mfu_mult(u).to_bits(),
                    resumed.mfu_mult(u).to_bits(),
                    "{kind:?}: client {u} mfu diverged at t={t}"
                );
                assert_eq!(
                    full.link_mult(u).to_bits(),
                    resumed.link_mult(u).to_bits(),
                    "{kind:?}: client {u} link diverged at t={t}"
                );
                assert_eq!(
                    full.is_available(u),
                    resumed.is_available(u),
                    "{kind:?}: client {u} availability diverged at t={t}"
                );
            }
        }
    }
}

/// Missing replay files fail loudly at timeline construction — the
/// session resume path inherits this (plus the content-hash check in
/// `Session::resume`).
#[test]
fn missing_replay_trace_file_fails_loudly() {
    let s = TraceSpec {
        kind: TraceKind::Replay,
        replay_path: "/nonexistent/sfl-trace.jsonl".into(),
        ..TraceSpec::default()
    };
    let err = EnvTimeline::new(&s, 4).unwrap_err().to_string();
    assert!(err.contains("sfl-trace.jsonl"), "error must name the file: {err}");
}

/// Churn (Markov availability) composes with scheduling: regret stays
/// finite, rounds with blackout are skipped, and the harness scores
/// every surviving round.
#[test]
fn markov_churn_regret_run_completes() {
    let mut rc = RegretConfig::new(spec(TraceKind::Markov));
    rc.n = 50;
    rc.rounds = 60;
    let rep = run_regret(&rc).unwrap();
    assert!(rep.rounds > 0 && rep.rounds <= 60);
    assert!(rep.oracle_total.is_finite() && rep.oracle_total > 0.0);
    assert!(rep.estimator.is_finite() && rep.nominal.is_finite() && rep.random.is_finite());
}
