//! Runtime integration: load the mini artifacts through PJRT and verify
//! the numerics against invariants established by the python test suite
//! (split == monolithic, LoRA-init no-op, loss decrease).
//!
//! Requires `make artifacts` (artifacts/mini). Tests share one engine —
//! PJRT client startup is expensive.

use sfl::lora::AdapterSet;
use sfl::runtime::{ClientState, Engine, ServerState};
use sfl::tensor::rng::Rng;
use std::path::Path;

fn engine() -> Engine {
    Engine::load(Path::new("artifacts"), "mini")
        .expect("artifacts/mini missing — run `make artifacts` first")
}

fn random_batch(e: &Engine, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let d = e.dims();
    let mut rng = Rng::new(seed);
    let tokens = (0..d.batch * d.seq).map(|_| rng.below(d.vocab) as i32).collect();
    let labels = (0..d.batch).map(|_| rng.below(d.classes) as i32).collect();
    (tokens, labels)
}

#[test]
fn full_runtime_stack() {
    let e = engine();
    let dims = e.dims().clone();
    let full = e.initial_lora().unwrap();
    let head = e.initial_head().unwrap();
    let (tokens, labels) = random_batch(&e, 1);

    // --- client_fwd: shapes + finiteness for every cut ---
    for &k in &dims.cuts {
        let (clora, _) = full.split_at(k).unwrap();
        let acts = e.client_fwd(k, &tokens, &clora).unwrap();
        assert_eq!(acts.shape, vec![dims.batch, dims.seq, dims.hidden]);
        assert!(acts.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }

    // --- split step == monolithic step (the core SFL property, now
    //     verified *through the rust runtime + HLO artifacts*) ---
    let k = 2usize;
    let (clora, slora) = full.split_at(k).unwrap();
    let cstate = ClientState::fresh(clora);
    let sstate = ServerState::fresh(slora, head.clone());
    let lr = 1e-3f32;

    let acts = e.client_fwd(k, &tokens, &cstate.lora).unwrap();
    let out = e.server_step(k, &acts, &labels, &sstate, lr).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert_eq!(out.act_grads.shape, acts.shape);
    let new_c = e.client_bwd(k, &tokens, &cstate, &out.act_grads, lr).unwrap();

    let full_state = ServerState::fresh(full.clone(), head.clone());
    let (floss, fstate) = e.full_step(&tokens, &labels, &full_state, lr).unwrap();
    assert!(
        (out.loss - floss).abs() < 1e-5,
        "split loss {} vs full loss {floss}",
        out.loss
    );
    let merged = AdapterSet::join(&new_c.lora, &out.state.lora).unwrap();
    let diff = merged.max_abs_diff(&fstate.lora).unwrap();
    assert!(diff < 1e-5, "adapter mismatch {diff}");

    // --- eval: logits shape, loss consistent with initial model ---
    let (logits, eloss) = e.eval(&tokens, &labels, &full, &head).unwrap();
    assert_eq!(logits.len(), dims.batch * dims.classes);
    assert!(eloss.is_finite());

    // --- B=0 LoRA init must be a no-op on the forward function ---
    let zero = AdapterSet::zeros(&dims, dims.layers);
    let (logits_zero, _) = e.eval(&tokens, &labels, &zero, &head).unwrap();
    let max_diff = logits
        .iter()
        .zip(logits_zero.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "B=0 adapter changed logits by {max_diff}");

    // --- a few monolithic steps on one batch reduce the loss ---
    let mut state = ServerState::fresh(full.clone(), head.clone());
    let mut losses = Vec::new();
    for _ in 0..6 {
        let (loss, next) = e.full_step(&tokens, &labels, &state, 5e-3).unwrap();
        losses.push(loss);
        state = next;
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.9),
        "loss did not decrease: {losses:?}"
    );

    // --- step counter advanced, Adam state became non-zero ---
    assert_eq!(state.step, 6);
    let m_norm: f32 = state.adam.m.iter().map(|t| {
        t.as_f32().unwrap().iter().map(|x| x.abs()).sum::<f32>()
    }).sum();
    assert!(m_norm > 0.0, "Adam moments never updated");

    // --- engine telemetry counted the executions ---
    assert!(e.exec_count.get() >= 12);
    assert!(e.bytes_uploaded.get() > 0);
}

#[test]
fn warmup_compiles_all_cut_artifacts() {
    let e = engine();
    e.warmup(&[1, 2, 3]).unwrap();
}

#[test]
fn manifest_rejects_wrong_batch_sizes() {
    let e = engine();
    let full = e.initial_lora().unwrap();
    let (clora, _) = full.split_at(1).unwrap();
    let err = e.client_fwd(1, &[0i32; 3], &clora);
    assert!(err.is_err(), "short token buffer must be rejected");
}

#[test]
fn determinism_same_inputs_same_loss() {
    let e = engine();
    let full = e.initial_lora().unwrap();
    let head = e.initial_head().unwrap();
    let (tokens, labels) = random_batch(&e, 7);
    let s = ServerState::fresh(full, head);
    let (l1, _) = e.full_step(&tokens, &labels, &s, 1e-3).unwrap();
    let (l2, _) = e.full_step(&tokens, &labels, &s, 1e-3).unwrap();
    assert_eq!(l1, l2, "executions must be deterministic");
}
