//! Runtime integration: load the mini artifacts through PJRT and verify
//! the numerics against invariants established by the python test suite
//! (split == monolithic, LoRA-init no-op, loss decrease).
//!
//! Requires `make artifacts` (artifacts/mini). Tests share one engine —
//! PJRT client startup is expensive.

use sfl::lora::AdapterSet;
use sfl::runtime::{ClientState, Engine, ServerState};
use sfl::tensor::rng::Rng;
use std::path::Path;

fn engine() -> Option<Engine> {
    if !Path::new("artifacts/mini/manifest.txt").exists() {
        eprintln!("skipping — artifacts/mini missing; run `make artifacts` first");
        return None;
    }
    let e = Engine::load(Path::new("artifacts"), "mini").expect("loading artifacts/mini");
    // The vendored xla stub can load artifacts but not compile them —
    // skip (rather than fail) until the real `xla` crate is swapped in.
    if let Err(err) = e.warmup(&[1]) {
        let msg = err.to_string();
        if msg.contains("offline xla stub") {
            eprintln!("skipping — vendored xla stub active; swap in the real `xla` crate (rust/Cargo.toml)");
            return None;
        }
        panic!("warmup(artifacts/mini) failed: {msg}");
    }
    Some(e)
}

fn random_batch(e: &Engine, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let d = e.dims();
    let mut rng = Rng::new(seed);
    let tokens = (0..d.batch * d.seq).map(|_| rng.below(d.vocab) as i32).collect();
    let labels = (0..d.batch).map(|_| rng.below(d.classes) as i32).collect();
    (tokens, labels)
}

#[test]
fn full_runtime_stack() {
    let Some(e) = engine() else { return };
    let dims = e.dims().clone();
    let full = e.initial_lora().unwrap();
    let head = e.initial_head().unwrap();
    let (tokens, labels) = random_batch(&e, 1);

    // --- client_fwd: shapes + finiteness for every cut ---
    for &k in &dims.cuts {
        let (clora, _) = full.split_at(k).unwrap();
        let acts = e.client_fwd(k, &tokens, &clora).unwrap();
        assert_eq!(acts.shape, vec![dims.batch, dims.seq, dims.hidden]);
        assert!(acts.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }

    // --- split step == monolithic step (the core SFL property, now
    //     verified *through the rust runtime + HLO artifacts*) ---
    let k = 2usize;
    let (clora, slora) = full.split_at(k).unwrap();
    let cstate = ClientState::fresh(clora);
    let sstate = ServerState::fresh(slora, head.clone());
    let lr = 1e-3f32;

    let acts = e.client_fwd(k, &tokens, &cstate.lora).unwrap();
    let out = e.server_step(k, &acts, &labels, &sstate, lr).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert_eq!(out.act_grads.shape, acts.shape);
    let new_c = e.client_bwd(k, &tokens, &cstate, &out.act_grads, lr).unwrap();

    let full_state = ServerState::fresh(full.clone(), head.clone());
    let (floss, fstate) = e.full_step(&tokens, &labels, &full_state, lr).unwrap();
    assert!(
        (out.loss - floss).abs() < 1e-5,
        "split loss {} vs full loss {floss}",
        out.loss
    );
    let merged = AdapterSet::join(&new_c.lora, &out.state.lora).unwrap();
    let diff = merged.max_abs_diff(&fstate.lora).unwrap();
    assert!(diff < 1e-5, "adapter mismatch {diff}");

    // --- eval: logits shape, loss consistent with initial model ---
    let (logits, eloss) = e.eval(&tokens, &labels, &full, &head).unwrap();
    assert_eq!(logits.len(), dims.batch * dims.classes);
    assert!(eloss.is_finite());

    // --- B=0 LoRA init must be a no-op on the forward function ---
    let zero = AdapterSet::zeros(&dims, dims.layers);
    let (logits_zero, _) = e.eval(&tokens, &labels, &zero, &head).unwrap();
    let max_diff = logits
        .iter()
        .zip(logits_zero.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "B=0 adapter changed logits by {max_diff}");

    // --- a few monolithic steps on one batch reduce the loss ---
    let mut state = ServerState::fresh(full.clone(), head.clone());
    let mut losses = Vec::new();
    for _ in 0..6 {
        let (loss, next) = e.full_step(&tokens, &labels, &state, 5e-3).unwrap();
        losses.push(loss);
        state = next;
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.9),
        "loss did not decrease: {losses:?}"
    );

    // --- step counter advanced, Adam state became non-zero ---
    assert_eq!(state.step, 6);
    let m_norm: f32 = state.adam.m.iter().map(|t| {
        t.as_f32().unwrap().iter().map(|x| x.abs()).sum::<f32>()
    }).sum();
    assert!(m_norm > 0.0, "Adam moments never updated");

    // --- engine telemetry counted the executions ---
    assert!(e.exec_count() >= 12);
    assert!(e.bytes_uploaded() > 0);
}

#[test]
fn warmup_compiles_all_cut_artifacts() {
    let Some(e) = engine() else { return };
    e.warmup(&[1, 2, 3]).unwrap();
}

#[test]
fn manifest_rejects_wrong_batch_sizes() {
    let Some(e) = engine() else { return };
    let full = e.initial_lora().unwrap();
    let (clora, _) = full.split_at(1).unwrap();
    let err = e.client_fwd(1, &[0i32; 3], &clora);
    assert!(err.is_err(), "short token buffer must be rejected");
}

#[test]
fn determinism_same_inputs_same_loss() {
    let Some(e) = engine() else { return };
    let full = e.initial_lora().unwrap();
    let head = e.initial_head().unwrap();
    let (tokens, labels) = random_batch(&e, 7);
    let s = ServerState::fresh(full, head);
    let (l1, _) = e.full_step(&tokens, &labels, &s, 1e-3).unwrap();
    let (l2, _) = e.full_step(&tokens, &labels, &s, 1e-3).unwrap();
    assert_eq!(l1, l2, "executions must be deterministic");
}

#[test]
fn in_place_step_apis_match_allocating_apis_bitwise() {
    // The zero-allocation path must be numerically indistinguishable
    // from the allocating one (same artifacts, same inputs), and must
    // not allocate a single HostTensor at steady state.
    let Some(e) = engine() else { return };
    let dims = e.dims().clone();
    let full = e.initial_lora().unwrap();
    let head = e.initial_head().unwrap();
    let (tokens, labels) = random_batch(&e, 3);
    let k = 2usize;
    let lr = 1e-3f32;
    let (clora, slora) = full.split_at(k).unwrap();

    // Reference: allocating path, two chained steps.
    let c0 = ClientState::fresh(clora);
    let s0 = ServerState::fresh(slora, head.clone());
    let acts_a = e.client_fwd(k, &tokens, &c0.lora).unwrap();
    let out_a = e.server_step(k, &acts_a, &labels, &s0, lr).unwrap();
    let c_a = e.client_bwd(k, &tokens, &c0, &out_a.act_grads, lr).unwrap();

    // In-place path from identical initial state, into scratch buffers.
    let mut c = c0.clone();
    let mut s = s0.clone();
    let mut acts = sfl::tensor::HostTensor::zeros(
        "acts",
        vec![dims.batch, dims.seq, dims.hidden],
    );
    let mut act_grads = sfl::tensor::HostTensor::zeros(
        "act_grads",
        vec![dims.batch, dims.seq, dims.hidden],
    );
    let before = sfl::tensor::alloc_count();
    e.client_fwd_into(k, &tokens, &c.lora, &mut acts).unwrap();
    let loss = e
        .server_step_into(k, &acts, &labels, &mut s, &mut act_grads, lr)
        .unwrap();
    e.client_bwd_into(k, &tokens, &mut c, &act_grads, lr).unwrap();
    assert_eq!(
        sfl::tensor::alloc_count(),
        before,
        "in-place step APIs must not allocate HostTensors"
    );

    assert_eq!(loss, out_a.loss, "loss must be bit-identical");
    assert_eq!(acts.as_f32().unwrap(), acts_a.as_f32().unwrap());
    assert_eq!(
        act_grads.as_f32().unwrap(),
        out_a.act_grads.as_f32().unwrap()
    );
    assert_eq!(s.lora.max_abs_diff(&out_a.state.lora).unwrap(), 0.0);
    assert_eq!(s.head.w.as_f32().unwrap(), out_a.state.head.w.as_f32().unwrap());
    assert_eq!(s.head.b.as_f32().unwrap(), out_a.state.head.b.as_f32().unwrap());
    assert_eq!(s.step, out_a.state.step);
    assert_eq!(c.lora.max_abs_diff(&c_a.lora).unwrap(), 0.0);
    for (x, y) in c.adam.m.iter().zip(c_a.adam.m.iter()) {
        assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
    }
    for (x, y) in s.adam.v.iter().zip(out_a.state.adam.v.iter()) {
        assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
    }
}
