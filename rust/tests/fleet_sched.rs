//! Fleet-scale scheduling: synthetic fleets + online timing estimation,
//! end to end on the analytic timing model (no artifacts needed).
//!
//! The acceptance gate lives here: on a stationary 1k-client synthetic
//! fleet with hidden per-device MFU jitter, the proposed scheduler
//! driven purely by the online `TimingEstimator` (static nominal model
//! at cold start, measured EWMAs after) must reach within 5% of the
//! oracle-timing makespan after a warm-up window.

use sfl::config::ExperimentConfig;
use sfl::coordinator::estimator::TimingEstimator;
use sfl::coordinator::scheduler::{makespan, ProposedScheduler, Scheduler};
use sfl::coordinator::timing::{build_jobs, build_nominal_jobs, StepTiming};
use sfl::devices::DEFAULT_CLIENT_MFU;
use sfl::fleet::{FleetPreset, FleetSpec};
use sfl::trace::NoisyObservation;

/// A synthesized fleet with its resolved cuts, true jobs, and the
/// static nominal-model jobs (what the cold-start scheduler sees).
struct Bench {
    cfg: ExperimentConfig,
    cuts: Vec<usize>,
}

impl Bench {
    fn new(preset: FleetPreset, n: usize, seed: u64, mfu_sigma: f64) -> Self {
        let mut spec = FleetSpec::new(preset, n, seed);
        spec.mfu_sigma = mfu_sigma;
        let mut cfg = ExperimentConfig::paper();
        cfg.apply_fleet(spec);
        cfg.validate().unwrap();
        let cuts = cfg.resolve_cuts();
        Self { cfg, cuts }
    }

    fn oracle_jobs(&self) -> Vec<sfl::coordinator::scheduler::JobInfo> {
        let dims = self.cfg.timing_dims();
        build_jobs(&dims, &self.cfg.clients, &self.cuts, &self.cfg.server)
    }

    fn nominal_jobs(&self) -> Vec<sfl::coordinator::scheduler::JobInfo> {
        let dims = self.cfg.timing_dims();
        build_nominal_jobs(&dims, &self.cfg.clients, &self.cuts, &self.cfg.server)
    }
}

#[test]
fn synthesized_fleets_are_deterministic_and_schedulable() {
    for preset in [FleetPreset::Paper, FleetPreset::Lognormal, FleetPreset::Zipf] {
        let a = Bench::new(preset, 200, 31, 0.2);
        let b = Bench::new(preset, 200, 31, 0.2);
        assert_eq!(a.cuts, b.cuts, "{preset}: cut assignment not deterministic");
        let (ja, jb) = (a.oracle_jobs(), b.oracle_jobs());
        for (x, y) in ja.iter().zip(jb.iter()) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "{preset}: jobs differ");
            assert_eq!(
                x.client_bwd_time.to_bits(),
                y.client_bwd_time.to_bits(),
                "{preset}: jobs differ"
            );
        }
        // The whole fleet schedules: valid index permutation, finite time.
        let mut order = Vec::new();
        ProposedScheduler.order_into(&ja, &mut order);
        let m = makespan(&ja, &order);
        assert!(m.is_finite() && m > 0.0, "{preset}: bad makespan {m}");
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..200).collect::<Vec<_>>(), "{preset}: not a permutation");
    }
}

#[test]
fn hidden_mfu_jitter_separates_nominal_from_true_timings() {
    let b = Bench::new(FleetPreset::Lognormal, 200, 23, 0.25);
    let (oracle, nominal) = (b.oracle_jobs(), b.nominal_jobs());
    // Nominal profiles assume the class-default MFU, so some clients'
    // true backward times must deviate — the signal the estimator learns.
    let deviating = oracle
        .iter()
        .zip(nominal.iter())
        .filter(|(o, s)| (o.client_bwd_time - s.client_bwd_time).abs() > 1e-9)
        .count();
    assert!(deviating > 100, "only {deviating}/200 clients deviate from nominal");
    // And the jitter is hidden from reported specs: same TFLOPS labels.
    for (o, s) in oracle.iter().zip(nominal.iter()) {
        assert_eq!(o.compute_capability.to_bits(), s.compute_capability.to_bits());
    }
    assert!(b.cfg.clients.iter().any(|c| (c.device.mfu - DEFAULT_CLIENT_MFU).abs() > 1e-3));
}

/// Acceptance gate: estimator-driven scheduling reaches within 5% of
/// the oracle makespan on a stationary 1k-client fleet after warm-up.
#[test]
fn estimator_within_5_percent_of_oracle_on_stationary_1k_fleet() {
    let b = Bench::new(FleetPreset::Lognormal, 1_000, 23, 0.25);
    let (oracle_jobs, nominal_jobs) = (b.oracle_jobs(), b.nominal_jobs());
    let mut sched = ProposedScheduler;
    let mut order = Vec::new();

    // Oracle reference: the scheduler sees the true timings.
    sched.order_into(&oracle_jobs, &mut order);
    let oracle_m = makespan(&oracle_jobs, &order);

    // Online path: the scheduler sees estimator output only; every
    // round the true (simulated) timings are observed back — exactly
    // the session's loop, run here on the timing model alone.
    let mut est = TimingEstimator::new(1_000, 0.25);
    let mut sched_jobs = Vec::new();
    let mut cold_m = 0.0;
    for round in 0..4 {
        est.jobs_into(&nominal_jobs, &mut sched_jobs);
        sched.order_into(&sched_jobs, &mut order);
        if round == 0 {
            cold_m = makespan(&oracle_jobs, &order);
        }
        for j in &oracle_jobs {
            est.observe(j.client, &StepTiming::from_job(j));
        }
    }
    assert_eq!(est.warm_clients(), 1_000);
    est.jobs_into(&nominal_jobs, &mut sched_jobs);
    // Discriminate a learning estimator from a static-model echo: after
    // warm-up on a stationary fleet the scheduler's view carries the
    // *true* (hidden-jitter) timings exactly — which the nominal model
    // does not predict (asserted in the mfu-jitter test above).
    for (s, o) in sched_jobs.iter().zip(oracle_jobs.iter()) {
        assert!(
            (s.client_bwd_time - o.client_bwd_time).abs() < 1e-9,
            "client {}: estimate {} never converged to truth {}",
            o.client,
            s.client_bwd_time,
            o.client_bwd_time
        );
    }
    sched.order_into(&sched_jobs, &mut order);
    let warm_m = makespan(&oracle_jobs, &order);

    assert!(
        warm_m <= oracle_m * 1.05,
        "estimator-driven makespan {warm_m:.3}s not within 5% of oracle {oracle_m:.3}s \
         (cold start was {cold_m:.3}s)"
    );
    // Cold start schedules on the static model's *predicted* tails —
    // a valid schedule in the same 5% envelope on this fleet (the
    // prediction error is bounded by the hidden MFU jitter).
    assert!(cold_m.is_finite() && cold_m <= oracle_m * 1.05, "cold {cold_m} vs {oracle_m}");
}

/// Measurement-noise robustness gate (ROADMAP item): on a *stationary*
/// fleet with lognormal observation noise (σ = 0.2 per timing channel),
/// the estimator-driven proposed schedule must stay within 10% of the
/// oracle makespan after a short warm-up — the envelope that justifies
/// the default `timing_ewma_alpha = 0.25` (see EXPERIMENTS.md §Traces:
/// the EWMA's steady-state noise-variance factor α/(2−α) ≈ 0.14 shrinks
/// a 20% per-observation error to ≈ 7.5% residual, while still moving
/// 1−(1−α)⁴ ≈ 68% of the way to a shifted truth within 4 rounds).
#[test]
fn estimator_stays_near_oracle_under_measurement_noise() {
    let b = Bench::new(FleetPreset::Lognormal, 500, 23, 0.25);
    let (oracle_jobs, nominal_jobs) = (b.oracle_jobs(), b.nominal_jobs());
    let mut sched = ProposedScheduler;
    let mut order = Vec::new();

    sched.order_into(&oracle_jobs, &mut order);
    let oracle_m = makespan(&oracle_jobs, &order);

    // The session's loop with the obs-noise knob on: every round the
    // estimator sees the true timings through the noise channel.
    let mut noise = NoisyObservation::new(99, 0.2);
    let mut est = TimingEstimator::new(500, 0.25);
    let mut sched_jobs = Vec::new();
    for _ in 0..8 {
        est.jobs_into(&nominal_jobs, &mut sched_jobs);
        sched.order_into(&sched_jobs, &mut order);
        for j in &oracle_jobs {
            est.observe(j.client, &StepTiming::from_job(j).noisy(&mut noise));
        }
    }
    assert_eq!(est.warm_clients(), 500);
    // The smoothed estimates must hug the truth: mean relative error of
    // the scheduling tail under the EWMA's residual-noise envelope.
    est.jobs_into(&nominal_jobs, &mut sched_jobs);
    let mut rel_err_sum = 0.0;
    for (s, o) in sched_jobs.iter().zip(oracle_jobs.iter()) {
        let truth = o.client_bwd_time + o.bwd_comm_time;
        rel_err_sum += ((s.client_bwd_time + s.bwd_comm_time) - truth).abs() / truth;
    }
    let mean_rel_err = rel_err_sum / 500.0;
    assert!(
        mean_rel_err < 0.12,
        "mean relative tail error {mean_rel_err:.4} exceeds the EWMA residual envelope"
    );
    // And the resulting schedule stays within the 10% makespan gate.
    sched.order_into(&sched_jobs, &mut order);
    let noisy_m = makespan(&oracle_jobs, &order);
    assert!(
        noisy_m <= oracle_m * 1.10,
        "noisy-estimator makespan {noisy_m:.3}s not within 10% of oracle {oracle_m:.3}s"
    );
}

#[test]
fn estimated_jobs_need_no_oracle_capability_inputs() {
    // After warm-up, the scheduler's view carries the *learned*
    // effective capability (N_c / measured tail), not the reported
    // TFLOPS — mis-reported specs cannot skew the order.
    let b = Bench::new(FleetPreset::Lognormal, 50, 29, 0.3);
    let (oracle_jobs, nominal_jobs) = (b.oracle_jobs(), b.nominal_jobs());
    let mut est = TimingEstimator::new(50, 0.25);
    for j in &oracle_jobs {
        est.observe(j.client, &StepTiming::from_job(j));
    }
    let mut sched_jobs = Vec::new();
    est.jobs_into(&nominal_jobs, &mut sched_jobs);
    for (s, o) in sched_jobs.iter().zip(oracle_jobs.iter()) {
        let tail = o.client_bwd_time + o.bwd_comm_time;
        assert!(
            (s.greedy_priority() - tail).abs() < 1e-9,
            "client {}: priority {} != measured tail {tail}",
            s.client,
            s.greedy_priority()
        );
    }
}
