//! Session checkpoint/resume equivalence: a run interrupted at a round
//! boundary and resumed from its checkpoint must produce a RunResult
//! bit-identical to the uninterrupted run — model state, optimizer
//! moments, batch-iterator and RNG streams, metric series, and traffic
//! counters all survive the round trip.
//!
//! Tests skip (with a note) when artifacts/mini is absent so the host-
//! side suite stays green on machines without the AOT toolchain.

use sfl::config::{ExperimentConfig, SchedulerKind, SchemeKind};
use sfl::coordinator::{RunResult, Session};
use sfl::faults::{AggKind, AttackKind};
use sfl::fleet::{FleetPreset, FleetSpec};
use sfl::runtime::Engine;
use sfl::trace::{TraceKind, TraceSpec};
use sfl::transport::{CompressKind, QuantKind};
use std::path::{Path, PathBuf};

fn engine() -> Option<Engine> {
    if !Path::new("artifacts/mini/manifest.txt").exists() {
        eprintln!("skipping — artifacts/mini missing; run `make artifacts` first");
        return None;
    }
    let e = Engine::load(Path::new("artifacts"), "mini").expect("loading artifacts/mini");
    if let Err(err) = e.warmup(&[1]) {
        let msg = err.to_string();
        if msg.contains("offline xla stub") {
            eprintln!("skipping — vendored xla stub active; swap in the real `xla` crate (rust/Cargo.toml)");
            return None;
        }
        panic!("warmup(artifacts/mini) failed: {msg}");
    }
    Some(e)
}

fn mini_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::mini();
    c.train.max_rounds = 6;
    c.train.steps_per_round = 2;
    c.train.eval_interval = 2;
    c.train.eval_batches = 4;
    c.train.aggregation_interval = 2;
    c.train.lr = 5e-3;
    c
}

fn ckpt_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("sfl_session_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.sflp"))
}

/// Bitwise comparison of every deterministic RunResult field
/// (wall_secs is wall-clock and excluded by construction).
fn assert_bit_identical(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{tag}: round count");
    for (x, y) in a.rounds.iter().zip(b.rounds.iter()) {
        assert_eq!(x.round, y.round, "{tag}: round id");
        assert_eq!(
            x.sim_time.to_bits(),
            y.sim_time.to_bits(),
            "{tag}: sim_time at round {}",
            x.round
        );
        assert_eq!(
            x.mean_loss.to_bits(),
            y.mean_loss.to_bits(),
            "{tag}: mean_loss at round {}",
            x.round
        );
    }
    for (name, sa, sb) in [("acc", &a.acc, &b.acc), ("f1", &a.f1, &b.f1)] {
        assert_eq!(sa.points.len(), sb.points.len(), "{tag}: {name} series length");
        for (x, y) in sa.points.iter().zip(sb.points.iter()) {
            assert_eq!(x.round, y.round, "{tag}: {name} round");
            assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{tag}: {name} time");
            assert_eq!(x.value.to_bits(), y.value.to_bits(), "{tag}: {name} value");
        }
    }
    assert_eq!(a.scheme, b.scheme, "{tag}: scheme");
    assert_eq!(a.scheduler, b.scheduler, "{tag}: scheduler label");
    assert_eq!(a.convergence_round, b.convergence_round, "{tag}: convergence round");
    assert_eq!(
        a.convergence_time.map(f64::to_bits),
        b.convergence_time.map(f64::to_bits),
        "{tag}: convergence time"
    );
    assert_eq!(a.final_acc.to_bits(), b.final_acc.to_bits(), "{tag}: final acc");
    assert_eq!(a.final_f1.to_bits(), b.final_f1.to_bits(), "{tag}: final f1");
    assert_eq!(a.adapter_switches, b.adapter_switches, "{tag}: switches");
    assert_eq!(a.executions, b.executions, "{tag}: executions");
    assert_eq!(a.uplink_bytes, b.uplink_bytes, "{tag}: uplink");
    assert_eq!(a.downlink_bytes, b.downlink_bytes, "{tag}: downlink");
}

fn roundtrip(e: &Engine, cfg: &ExperimentConfig, tag: &str) {
    // Uninterrupted reference run.
    let mut full = Session::new(e, cfg).unwrap();
    let reference = full.run_to_convergence().unwrap();

    // Interrupt after 3 rounds, checkpoint, resume, finish.
    let mut first = Session::new(e, cfg).unwrap();
    for _ in 0..3 {
        first.step_round().unwrap();
    }
    let path = ckpt_path(tag);
    first.checkpoint(&path).unwrap();
    drop(first);

    let mut resumed = Session::resume(e, cfg, &path).unwrap();
    assert_eq!(resumed.round(), 3, "{tag}: resumed at wrong round");
    let result = resumed.run_to_convergence().unwrap();
    assert_bit_identical(&reference, &result, tag);
}

#[test]
fn ours_checkpoint_resume_is_bit_identical() {
    let Some(e) = engine() else { return };
    roundtrip(&e, &mini_cfg(), "ours");
}

#[test]
fn ours_with_dropout_and_random_scheduler_resumes_bit_identical() {
    // Exercises every RNG stream the checkpoint must capture: dropout
    // sampling, the random scheduler, and the batch iterators.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.scheduler = SchedulerKind::Random;
    cfg.train.dropout_prob = 0.3;
    roundtrip(&e, &cfg, "ours-dropout-random");
}

#[test]
fn sl_checkpoint_resume_is_bit_identical() {
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.scheme = SchemeKind::Sl;
    roundtrip(&e, &cfg, "sl");
}

#[test]
fn sfl_checkpoint_resume_is_bit_identical() {
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.scheme = SchemeKind::Sfl;
    roundtrip(&e, &cfg, "sfl");
}

#[test]
fn non_stationary_trace_checkpoint_resume_is_bit_identical() {
    // The acceptance property: a checkpointed mid-trace session resumes
    // with a bit-identical remaining trajectory — timeline RNG streams,
    // noisy-observation RNG, estimator state, and the resulting
    // sim-clock all survive the round trip.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.trace = TraceSpec {
        kind: TraceKind::RandomWalk,
        seed: 13,
        mfu_sigma: 0.1,
        link_sigma: 0.08,
        obs_noise_sigma: 0.15,
        ..TraceSpec::default()
    };
    roundtrip(&e, &cfg, "trace-walk");

    let mut churn = mini_cfg();
    churn.trace = TraceSpec {
        kind: TraceKind::Markov,
        seed: 13,
        mean_up: 40.0,
        mean_down: 15.0,
        ..TraceSpec::default()
    };
    roundtrip(&e, &churn, "trace-markov");
}

/// A pooled bench-scale-shaped config: 24 synthetic clients, bounded
/// 3-client cohorts, residency cap 2 (so evictions and spills happen),
/// dropout + a random-walk trace + the random scheduler — every RNG
/// stream plus the pool machinery in one run.
fn pooled_cfg() -> ExperimentConfig {
    let mut c = mini_cfg();
    c.apply_fleet(FleetSpec::new(FleetPreset::Paper, 24, 3));
    c.train.max_participants = 3;
    c.train.dropout_prob = 0.3;
    c.scheduler = SchedulerKind::Random;
    c.pool.state_cap = 2;
    c.trace = TraceSpec {
        kind: TraceKind::RandomWalk,
        seed: 13,
        mfu_sigma: 0.1,
        link_sigma: 0.08,
        obs_noise_sigma: 0.15,
        ..TraceSpec::default()
    };
    c
}

#[test]
fn pooled_session_matches_eager_bitwise() {
    // The state pool is a memory optimization, not a numeric change:
    // the pooled run must reproduce the eager run bit-for-bit — losses,
    // sim clock, eval series, traffic — on the same fleet.
    let Some(e) = engine() else { return };
    let pooled = pooled_cfg();
    let mut eager = pooled.clone();
    eager.pool.state_cap = 0;
    let rp = Session::new(&e, &pooled).unwrap().run_to_convergence().unwrap();
    let re = Session::new(&e, &eager).unwrap().run_to_convergence().unwrap();
    assert_bit_identical(&re, &rp, "pooled-vs-eager");
}

#[test]
fn pooled_sparse_checkpoint_resume_is_bit_identical() {
    // Satellite: resume a pooled session mid-run — some clients
    // resident, some spilled, most never materialized — under dropout +
    // a random-walk trace, and replay the remaining rounds
    // bit-identically.  Also resume the same sparse checkpoint under a
    // different pool cap (including eager): the cap is not part of the
    // fingerprint because it never changes numerics.
    let Some(e) = engine() else { return };
    let cfg = pooled_cfg();
    let mut full = Session::new(&e, &cfg).unwrap();
    let reference = full.run_to_convergence().unwrap();

    let mut first = Session::new(&e, &cfg).unwrap();
    for _ in 0..3 {
        first.step_round().unwrap();
    }
    let st = first.pool_stats().expect("pooled session must report pool stats");
    let materialized = st.resident + st.spilled;
    assert!(
        materialized < 24,
        "3 bounded rounds cannot have materialized the whole fleet ({materialized}/24)"
    );
    assert!(st.resident <= 3, "residency must stay within max(cap, cohort)");
    let path = ckpt_path("pooled-sparse");
    first.checkpoint(&path).unwrap();
    drop(first);

    let mut resumed = Session::resume(&e, &cfg, &path).unwrap();
    assert_eq!(resumed.round(), 3);
    let result = resumed.run_to_convergence().unwrap();
    assert_bit_identical(&reference, &result, "pooled-sparse");

    // Same checkpoint, different (eager) residency on resume.
    let mut eager = cfg.clone();
    eager.pool.state_cap = 0;
    let mut resumed_eager = Session::resume(&e, &eager, &path).unwrap();
    let result_eager = resumed_eager.run_to_convergence().unwrap();
    assert_bit_identical(&reference, &result_eager, "pooled-sparse-eager-resume");
}

#[test]
fn resume_rejects_mismatched_trace_spec() {
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.trace.kind = TraceKind::RandomWalk;
    let mut s = Session::new(&e, &cfg).unwrap();
    s.step_round().unwrap();
    let path = ckpt_path("trace-mismatch");
    s.checkpoint(&path).unwrap();
    // Different trace seed → different timeline streams → refuse.
    let mut reseeded = cfg.clone();
    reseeded.trace.seed += 1;
    assert!(Session::resume(&e, &reseeded, &path).is_err());
    // Dropping the trace entirely is also a mismatch.
    let mut stat = cfg.clone();
    stat.trace = TraceSpec::default();
    assert!(Session::resume(&e, &stat, &path).is_err());
}

#[test]
fn resume_fails_loudly_when_replay_trace_file_is_missing_or_changed() {
    let Some(e) = engine() else { return };
    let dir = std::env::temp_dir().join("sfl_session_replay_test");
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("mfu.jsonl");
    std::fs::write(&trace_path, "{\"t\": 0.0, \"v\": 1.0}\n{\"t\": 50.0, \"v\": 0.6}\n").unwrap();
    let mut cfg = mini_cfg();
    cfg.trace = TraceSpec {
        kind: TraceKind::Replay,
        replay_path: trace_path.to_string_lossy().into_owned(),
        ..TraceSpec::default()
    };
    let mut s = Session::new(&e, &cfg).unwrap();
    for _ in 0..2 {
        s.step_round().unwrap();
    }
    let ckpt = ckpt_path("trace-replay");
    s.checkpoint(&ckpt).unwrap();
    drop(s);

    // Changed content → content-hash mismatch, loud refusal.
    std::fs::write(&trace_path, "{\"t\": 0.0, \"v\": 2.0}\n").unwrap();
    let err = Session::resume(&e, &cfg, &ckpt).unwrap_err().to_string();
    assert!(err.contains("replay trace"), "unexpected error: {err}");

    // Missing file → loud failure at timeline construction.
    std::fs::remove_file(&trace_path).unwrap();
    let err = Session::resume(&e, &cfg, &ckpt).unwrap_err().to_string();
    assert!(err.contains("mfu.jsonl"), "error must name the missing file: {err}");

    // Restored content → resume works again.
    std::fs::write(&trace_path, "{\"t\": 0.0, \"v\": 1.0}\n{\"t\": 50.0, \"v\": 0.6}\n").unwrap();
    let mut resumed = Session::resume(&e, &cfg, &ckpt).unwrap();
    assert_eq!(resumed.round(), 2);
    resumed.step_round().unwrap();
}

#[test]
fn benign_robust_pipeline_is_bitwise_identical_to_plain() {
    // The full robust path — staging, committee draws, sanitizer norm
    // scan, trimmed kernel — with zero attackers and degenerate knobs
    // (trim 0) must reproduce today's plain trajectory *bit-for-bit*:
    // the defenses are observers until something actually misbehaves.
    let Some(e) = engine() else { return };
    let plain = mini_cfg();
    let mut benign = plain.clone();
    benign.robust.agg = AggKind::Trimmed;
    benign.robust.trim = 0;
    benign.robust.sanitize = true;
    benign.robust.verify_frac = 0.25;
    let rp = Session::new(&e, &plain).unwrap().run_to_convergence().unwrap();
    let rb = Session::new(&e, &benign).unwrap().run_to_convergence().unwrap();
    assert_bit_identical(&rp, &rb, "benign-robust");
}

#[test]
fn robust_session_under_stale_attack_resumes_bit_identical() {
    // The adversarial round trip: stale-replay attackers (whose banked
    // previous-round halves must be serialized), a trimmed-mean merge,
    // and a spot-verification committee mid-quarantine — fault RNG,
    // committee RNG, and the quarantine mask all survive resume.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.robust.attack = AttackKind::Stale;
    cfg.robust.attack_frac = 0.3;
    cfg.robust.agg = AggKind::Trimmed;
    cfg.robust.trim = 1;
    cfg.robust.verify_frac = 0.25;
    roundtrip(&e, &cfg, "robust-stale");

    let mut scaled = mini_cfg();
    scaled.robust.attack = AttackKind::Scale;
    scaled.robust.attack_frac = 0.2;
    scaled.robust.attack_lambda = -4.0;
    scaled.robust.agg = AggKind::Clip;
    scaled.robust.clip = 0.5;
    scaled.robust.sanitize = true;
    roundtrip(&e, &scaled, "robust-scale-clip");
}

#[test]
fn resume_rejects_changed_robust_config() {
    // The robust knobs are fingerprinted: resuming under a different
    // attack fraction — or with the defenses switched off entirely —
    // must refuse rather than silently change the threat model.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.robust.attack = AttackKind::Scale;
    cfg.robust.attack_frac = 0.2;
    cfg.robust.agg = AggKind::Trimmed;
    cfg.robust.trim = 1;
    let mut s = Session::new(&e, &cfg).unwrap();
    s.step_round().unwrap();
    let path = ckpt_path("robust-mismatch");
    s.checkpoint(&path).unwrap();

    let mut refrac = cfg.clone();
    refrac.robust.attack_frac = 0.4;
    assert!(Session::resume(&e, &refrac, &path).is_err());

    let mut disarmed = cfg.clone();
    disarmed.robust = Default::default();
    assert!(Session::resume(&e, &disarmed, &path).is_err());

    let resumable = Session::resume(&e, &cfg, &path);
    assert!(resumable.is_ok(), "unchanged robust config must resume");
}

fn transport_cfg(frac: f64, quant: QuantKind, ef: bool) -> ExperimentConfig {
    let mut c = mini_cfg();
    c.transport.compress = CompressKind::TopK;
    c.transport.topk_frac = frac;
    c.transport.quant = quant;
    c.transport.error_feedback = ef;
    c
}

#[test]
fn degenerate_transport_is_bit_identical_to_dense_including_checkpoints() {
    // Top-k at 100% / f32 / no error feedback never constructs a codec
    // (a delta codec cannot round-trip bit-exactly), so the degenerate
    // config must reproduce the dense run completely: trajectory,
    // traffic counters, round reports, and the checkpoint bytes.
    let Some(e) = engine() else { return };
    let dense = mini_cfg();
    let degenerate = transport_cfg(1.0, QuantKind::F32, false);
    let rd = Session::new(&e, &dense).unwrap().run_to_convergence().unwrap();
    let rt = Session::new(&e, &degenerate).unwrap().run_to_convergence().unwrap();
    assert_bit_identical(&rd, &rt, "degenerate-transport");

    let mut sd = Session::new(&e, &dense).unwrap();
    let mut st = Session::new(&e, &degenerate).unwrap();
    for _ in 0..3 {
        sd.step_round().unwrap();
        let r = st.step_round().unwrap();
        assert!(r.transport.is_none(), "degenerate transport must not report stats");
    }
    let pd = ckpt_path("transport-dense");
    let pt = ckpt_path("transport-degenerate");
    sd.checkpoint(&pd).unwrap();
    st.checkpoint(&pt).unwrap();
    let bd = std::fs::read(&pd).unwrap();
    let bt = std::fs::read(&pt).unwrap();
    assert!(bd == bt, "degenerate transport checkpoint layout must equal dense");
    // The shared layout means a dense checkpoint resumes either way.
    let mut resumed = Session::resume(&e, &degenerate, &pd).unwrap();
    resumed.step_round().unwrap();
}

#[test]
fn transport_session_with_error_feedback_resumes_bit_identical() {
    // Error-feedback residuals are durable per-client state: they ride
    // the checkpoint (like Adam moments), so an interrupted compressed
    // run replays its remaining rounds bit-identically — including the
    // billed (encoded-size) traffic counters.
    let Some(e) = engine() else { return };
    roundtrip(&e, &transport_cfg(0.25, QuantKind::Q8, true), "transport-ef");
    roundtrip(&e, &transport_cfg(0.5, QuantKind::Q4, false), "transport-q4");
}

#[test]
fn pooled_transport_session_resumes_bit_identical() {
    // EF residuals also spill/reload through the state pool; a sparse
    // checkpoint (some residual vectors never materialized) must still
    // resume bit-exactly.
    let Some(e) = engine() else { return };
    let mut cfg = pooled_cfg();
    cfg.transport.compress = CompressKind::TopK;
    cfg.transport.topk_frac = 0.25;
    cfg.transport.quant = QuantKind::Q8;
    cfg.transport.error_feedback = true;
    roundtrip(&e, &cfg, "transport-pooled");
}

#[test]
fn async_transport_session_resumes_bit_identical() {
    // Under `--async` each upload encodes against its dispatch baseline
    // (b_v), and the decoded update feeds the staleness delta-correction.
    // The EF residuals and version-indexed baselines all survive resume.
    let Some(e) = engine() else { return };
    let mut cfg = transport_cfg(0.25, QuantKind::Q8, true);
    cfg.asynchrony.enabled = true;
    cfg.asynchrony.buffer_k = 2;
    cfg.asynchrony.staleness_bound = 30.0;
    cfg.asynchrony.staleness_beta = 0.5;
    roundtrip(&e, &cfg, "transport-async");
}

#[test]
fn resume_rejects_changed_transport_config() {
    // Active transport knobs are fingerprinted: resuming under a
    // different sparsity/precision — or with compression off — would
    // silently change the arithmetic, so it must refuse.
    let Some(e) = engine() else { return };
    let cfg = transport_cfg(0.25, QuantKind::Q8, true);
    let mut s = Session::new(&e, &cfg).unwrap();
    for _ in 0..2 {
        s.step_round().unwrap();
    }
    let path = ckpt_path("transport-mismatch");
    s.checkpoint(&path).unwrap();
    drop(s);

    let mut refrac = cfg.clone();
    refrac.transport.topk_frac = 0.5;
    assert!(Session::resume(&e, &refrac, &path).is_err());

    let mut requant = cfg.clone();
    requant.transport.quant = QuantKind::Q4;
    assert!(Session::resume(&e, &requant, &path).is_err());

    let mut off = cfg.clone();
    off.transport = Default::default();
    assert!(Session::resume(&e, &off, &path).is_err());

    assert!(Session::resume(&e, &cfg, &path).is_ok(), "unchanged transport config must resume");
}

#[test]
fn tampered_transport_payload_is_flagged_into_quarantine() {
    // A hash-failing payload under the robust path is hard evidence:
    // the sender is flagged (and quarantined) like a witness-caught
    // liar, its upload never reaches the merge, and honest clients'
    // compressed updates keep flowing.
    let Some(e) = engine() else { return };
    let mut cfg = transport_cfg(0.25, QuantKind::Q8, true);
    cfg.train.aggregation_interval = 1;
    cfg.robust.verify_frac = 0.25;
    let mut s = Session::new(&e, &cfg).unwrap();
    s.transport_tamper_next(1);
    let r1 = s.step_round().unwrap();
    let rb = r1.robust.expect("robust stats must stream when the committee is armed");
    assert_eq!(rb.flagged, 1, "the tampered sender must be flagged");
    assert_eq!(rb.quarantined, 1, "the tampered sender must be quarantined");
    let tp = r1.transport.expect("active transport must stream stats");
    assert!(tp.ratio > 1.0, "q8 top-k uplink must beat dense (ratio {})", tp.ratio);
    assert!(tp.ef_norm > 0.0, "error feedback must carry residual mass");
    assert!(tp.up_bytes < tp.down_bytes, "compressed uplink must undercut the dense downlink");

    // Later rounds: no new flags, the quarantine count persists, and
    // merges keep succeeding without the quarantined client.
    let r2 = s.step_round().unwrap();
    let rb2 = r2.robust.unwrap();
    assert_eq!(rb2.flagged, 0, "honest payloads must pass verification");
    assert_eq!(rb2.quarantined, 1);
    assert!(r2.transport.is_some());
}

#[test]
fn resume_rejects_mismatched_scheme() {
    let Some(e) = engine() else { return };
    let cfg = mini_cfg();
    let mut s = Session::new(&e, &cfg).unwrap();
    s.step_round().unwrap();
    let path = ckpt_path("mismatch");
    s.checkpoint(&path).unwrap();
    let mut other = cfg.clone();
    other.scheme = SchemeKind::Sl;
    assert!(Session::resume(&e, &other, &path).is_err());
}

#[test]
fn resume_rejects_mismatched_train_config() {
    // The fingerprinted knobs (seed, scheduler, intervals, lr, ...)
    // must match — restored iterator/RNG streams would otherwise replay
    // against different data or policies.  max_rounds may differ
    // (extending a resumed run's horizon is legitimate).
    let Some(e) = engine() else { return };
    let cfg = mini_cfg();
    let mut s = Session::new(&e, &cfg).unwrap();
    s.step_round().unwrap();
    let path = ckpt_path("train-mismatch");
    s.checkpoint(&path).unwrap();

    let mut seeded = cfg.clone();
    seeded.train.seed += 1;
    assert!(Session::resume(&e, &seeded, &path).is_err());

    let mut resched = cfg.clone();
    resched.scheduler = SchedulerKind::Fifo;
    assert!(Session::resume(&e, &resched, &path).is_err());

    let mut extended = cfg.clone();
    extended.train.max_rounds += 10;
    assert!(Session::resume(&e, &extended, &path).is_ok());
}
