//! Discrete-event round engine properties:
//!
//!  1. Synchronous rounds driven through the event engine (the default
//!     `step_round` path, which schedules the barrier as an
//!     `AggregationTrigger` event) are bit-identical to the direct
//!     accrual path (`step_round_reference`) — the engine is pure
//!     plumbing until `--async` turns on buffered aggregation.
//!  2. Asynchronous runs are seed-deterministic.
//!  3. An async session checkpointed between merges — event queue,
//!     version vectors, in-flight client state, and dispatch baselines
//!     all live — resumes bit-identically.
//!
//! Tests skip (with a note) when artifacts/mini is absent so the host-
//! side suite stays green on machines without the AOT toolchain.

use sfl::config::{ExperimentConfig, SchedulerKind, SchemeKind};
use sfl::coordinator::{RoundReport, RunResult, Session};
use sfl::faults::{AggKind, AttackKind};
use sfl::runtime::Engine;
use sfl::trace::{TraceKind, TraceSpec};
use std::path::{Path, PathBuf};

fn engine() -> Option<Engine> {
    if !Path::new("artifacts/mini/manifest.txt").exists() {
        eprintln!("skipping — artifacts/mini missing; run `make artifacts` first");
        return None;
    }
    let e = Engine::load(Path::new("artifacts"), "mini").expect("loading artifacts/mini");
    if let Err(err) = e.warmup(&[1]) {
        let msg = err.to_string();
        if msg.contains("offline xla stub") {
            eprintln!("skipping — vendored xla stub active; swap in the real `xla` crate (rust/Cargo.toml)");
            return None;
        }
        panic!("warmup(artifacts/mini) failed: {msg}");
    }
    Some(e)
}

fn mini_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::mini();
    c.train.max_rounds = 6;
    c.train.steps_per_round = 2;
    c.train.eval_interval = 2;
    c.train.eval_batches = 4;
    c.train.aggregation_interval = 2;
    c.train.lr = 5e-3;
    c
}

fn async_cfg() -> ExperimentConfig {
    let mut c = mini_cfg();
    c.asynchrony.enabled = true;
    c.asynchrony.buffer_k = 2;
    c.asynchrony.staleness_bound = 30.0;
    c.asynchrony.staleness_beta = 0.5;
    c
}

fn assert_report_eq(a: &RoundReport, b: &RoundReport, tag: &str) {
    assert_eq!(a.round, b.round, "{tag}: round id");
    assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{tag}: sim_time @r{}", a.round);
    assert_eq!(a.step_time.to_bits(), b.step_time.to_bits(), "{tag}: step_time @r{}", a.round);
    assert_eq!(a.mean_loss.to_bits(), b.mean_loss.to_bits(), "{tag}: mean_loss @r{}", a.round);
    assert_eq!(a.participants, b.participants, "{tag}: participants @r{}", a.round);
    match (&a.eval, &b.eval) {
        (None, None) => {}
        (Some(x), Some(y)) => {
            assert_eq!(x.acc.to_bits(), y.acc.to_bits(), "{tag}: acc @r{}", a.round);
            assert_eq!(x.f1.to_bits(), y.f1.to_bits(), "{tag}: f1 @r{}", a.round);
            assert_eq!(x.converged, y.converged, "{tag}: converged @r{}", a.round);
        }
        _ => panic!("{tag}: eval presence differs at round {}", a.round),
    }
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{tag}: round count");
    for (x, y) in a.rounds.iter().zip(b.rounds.iter()) {
        assert_eq!(x.round, y.round, "{tag}: round id");
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{tag}: time @r{}", x.round);
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{tag}: loss @r{}", x.round);
    }
    for (name, sa, sb) in [("acc", &a.acc, &b.acc), ("f1", &a.f1, &b.f1)] {
        assert_eq!(sa.points.len(), sb.points.len(), "{tag}: {name} series length");
        for (x, y) in sa.points.iter().zip(sb.points.iter()) {
            assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{tag}: {name} time");
            assert_eq!(x.value.to_bits(), y.value.to_bits(), "{tag}: {name} value");
        }
    }
    assert_eq!(a.convergence_round, b.convergence_round, "{tag}: convergence round");
    assert_eq!(a.final_acc.to_bits(), b.final_acc.to_bits(), "{tag}: final acc");
    assert_eq!(a.final_f1.to_bits(), b.final_f1.to_bits(), "{tag}: final f1");
    assert_eq!(a.executions, b.executions, "{tag}: executions");
    assert_eq!(a.uplink_bytes, b.uplink_bytes, "{tag}: uplink");
    assert_eq!(a.downlink_bytes, b.downlink_bytes, "{tag}: downlink");
}

/// Drive one session through the engine and a twin directly; every
/// per-round report must match bit-for-bit.
fn sync_twin(e: &Engine, cfg: &ExperimentConfig, tag: &str) {
    let mut via = Session::new(e, cfg).unwrap();
    let mut direct = Session::new(e, cfg).unwrap();
    for _ in 0..cfg.train.max_rounds {
        let a = via.step_round().unwrap();
        let b = direct.step_round_reference().unwrap();
        assert!(a.asynchrony.is_none(), "{tag}: sync rounds must not report async stats");
        assert_report_eq(&a, &b, tag);
    }
}

#[test]
fn sync_via_engine_is_bit_identical_to_reference() {
    let Some(e) = engine() else { return };
    sync_twin(&e, &mini_cfg(), "ours");

    let mut sfl_cfg = mini_cfg();
    sfl_cfg.scheme = SchemeKind::Sfl;
    sync_twin(&e, &sfl_cfg, "sfl");

    let mut sl_cfg = mini_cfg();
    sl_cfg.scheme = SchemeKind::Sl;
    sync_twin(&e, &sl_cfg, "sl");
}

#[test]
fn sync_via_engine_matches_reference_under_churn_and_attack() {
    // The hostile composition: markov availability churn, dropout, the
    // random scheduler, a scale attack behind a trimmed merge and a
    // spot-check committee with probation re-admission — the engine
    // barrier must stay invisible through all of it.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.scheduler = SchedulerKind::Random;
    cfg.train.dropout_prob = 0.3;
    cfg.trace = TraceSpec {
        kind: TraceKind::Markov,
        seed: 13,
        mean_up: 40.0,
        mean_down: 15.0,
        ..TraceSpec::default()
    };
    cfg.robust.attack = AttackKind::Scale;
    cfg.robust.attack_frac = 0.2;
    cfg.robust.attack_lambda = -4.0;
    cfg.robust.agg = AggKind::Trimmed;
    cfg.robust.trim = 1;
    cfg.robust.verify_frac = 0.25;
    cfg.robust.quarantine_ttl = 2;
    sync_twin(&e, &cfg, "churn-attack");
}

#[test]
fn async_run_is_seed_deterministic_and_reports_async_stats() {
    let Some(e) = engine() else { return };
    let cfg = async_cfg();
    let ra = Session::new(&e, &cfg).unwrap().run_to_convergence().unwrap();
    let rb = Session::new(&e, &cfg).unwrap().run_to_convergence().unwrap();
    assert_bit_identical(&ra, &rb, "async-determinism");
    assert!(!ra.rounds.is_empty(), "async run must complete rounds");

    // The async block is live: every merge reports buffered counts and
    // a monotone absolute engine clock.
    let mut s = Session::new(&e, &cfg).unwrap();
    let mut prev_clock = 0.0f64;
    for _ in 0..cfg.train.max_rounds {
        let r = s.step_round().unwrap();
        let a = r.asynchrony.expect("async rounds must carry AsyncStats");
        assert!(a.buffered >= 1, "a merge needs at least one buffered update");
        assert!(a.merged >= 1 && a.merged <= a.buffered);
        assert!(a.wall_clock >= prev_clock, "engine clock must be monotone");
        assert!(!r.participants.is_empty());
        prev_clock = a.wall_clock;
    }
}

fn ckpt_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("sfl_events_async_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.sflp"))
}

#[test]
fn async_checkpoint_resume_with_inflight_clients_is_bit_identical() {
    // Interrupt an async run between merges: dispatched-but-undelivered
    // client updates, the event queue, version vectors, and the dispatch
    // baselines for delta correction are all live in the checkpoint.
    let Some(e) = engine() else { return };
    let cfg = async_cfg();
    let mut full = Session::new(&e, &cfg).unwrap();
    let reference = full.run_to_convergence().unwrap();

    let mut first = Session::new(&e, &cfg).unwrap();
    for _ in 0..3 {
        first.step_round().unwrap();
    }
    let path = ckpt_path("async-midflight");
    first.checkpoint(&path).unwrap();
    drop(first);

    let mut resumed = Session::resume(&e, &cfg, &path).unwrap();
    assert_eq!(resumed.round(), 3, "resumed at wrong round");
    let result = resumed.run_to_convergence().unwrap();
    assert_bit_identical(&reference, &result, "async-midflight");
}

#[test]
fn async_resume_rejects_changed_async_config() {
    // The async knobs are fingerprinted: a different staleness bound or
    // buffer size changes merge timing, so resume must refuse.
    let Some(e) = engine() else { return };
    let cfg = async_cfg();
    let mut s = Session::new(&e, &cfg).unwrap();
    s.step_round().unwrap();
    let path = ckpt_path("async-mismatch");
    s.checkpoint(&path).unwrap();
    drop(s);

    let mut rebuffered = cfg.clone();
    rebuffered.asynchrony.buffer_k = 3;
    assert!(Session::resume(&e, &rebuffered, &path).is_err());

    let mut rebounded = cfg.clone();
    rebounded.asynchrony.staleness_bound = 10.0;
    assert!(Session::resume(&e, &rebounded, &path).is_err());

    let mut disabled = cfg.clone();
    disabled.asynchrony.enabled = false;
    assert!(Session::resume(&e, &disabled, &path).is_err());

    assert!(Session::resume(&e, &cfg, &path).is_ok(), "unchanged async config must resume");
}
