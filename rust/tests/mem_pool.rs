//! Acceptance gate for the pooled client state (ISSUE 5): on a
//! 10k-client fleet with a 32-client cohort, pooled peak resident state
//! must be ≤ 5% of the eager footprint, with zero `HostTensor`
//! allocations per round after warm-up and bit-exact spill round trips.
//! Pure host-side — no PJRT artifacts needed (pooled-vs-eager numeric
//! bit-identity is asserted by the artifact-gated session suites).

use sfl::data::{self, DataPool};
use sfl::lora::AdapterSet;
use sfl::model::{memory, ModelDims};
use sfl::pool::StatePool;
use sfl::runtime::HeadState;
use sfl::tensor::{alloc_count, rng::Rng, HostTensor};

fn mk_head(d: &ModelDims) -> HeadState {
    HeadState {
        w: HostTensor::zeros("head.w", vec![d.hidden, d.classes]),
        b: HostTensor::zeros("head.b", vec![d.classes]),
    }
}

fn fleet(n: usize, cap: usize) -> (ModelDims, Vec<usize>, DataPool, StatePool) {
    let d = ModelDims::mini();
    let spec = data::CorpusSpec {
        train_size: 2_000,
        test_size: 100,
        ..data::CorpusSpec::carer_like(d.vocab, d.seq)
    };
    let ds = data::generate(&spec);
    let cuts: Vec<usize> = (0..n).map(|u| d.cuts[u % d.cuts.len()]).collect();
    let dpool = DataPool::new(&ds.train, n, 0.5, 11, d.batch);
    let full0 = AdapterSet::init(&d, d.layers, 42);
    let head0 = mk_head(&d);
    let pool = StatePool::new(&d, &cuts, full0, head0, 100, cap, &dpool).unwrap();
    (d, cuts, dpool, pool)
}

#[test]
fn pooled_resident_state_is_o_active_on_a_10k_fleet() {
    const N: usize = 10_000;
    const COHORT: usize = 32;
    const ROUNDS: u64 = 12;
    const WARMUP: u64 = 4;
    let (d, cuts, dpool, mut pool) = fleet(N, COHORT);
    assert!(dpool.is_shared(), "10k clients over a 2k corpus must use the shared data pool");

    let mut ids: Vec<usize> = (0..N).collect();
    let mut rng = Rng::new(5);
    let mut steady_base = 0u64;
    for round in 1..=ROUNDS {
        if round == WARMUP + 1 {
            steady_base = alloc_count();
        }
        for i in 0..COHORT {
            let j = i + rng.below(N - i);
            ids.swap(i, j);
        }
        pool.begin_round(round, COHORT).unwrap();
        for &u in &ids[..COHORT] {
            let slot = pool.acquire(u, &dpool).unwrap();
            let _ = slot.it.next_batch();
            slot.cs.step += 1;
            slot.cs.adam.m[0].as_f32_mut().unwrap()[0] += 1.0;
        }
    }
    assert_eq!(
        alloc_count() - steady_base,
        0,
        "pooled rounds after warm-up must allocate zero HostTensors"
    );

    let st = pool.stats();
    let eager = pool.eager_state_bytes();
    assert!(st.resident <= COHORT);
    assert!(
        st.peak_resident_bytes * 20 <= eager,
        "pooled peak {} B exceeds 5% of eager {} B",
        st.peak_resident_bytes,
        eager
    );
    assert!(st.evictions > 0, "random 32-cohorts over 10k clients must evict");
    assert_eq!(st.resident_bytes, st.resident as u64 * pool.bytes_per_client());

    // The analytic accountant agrees: resident client state is
    // O(cohort), not O(fleet).
    let analytic_eager = memory::ours_server_memory(&d, &cuts).lora_states;
    let analytic_pooled =
        memory::pooled_server_memory(&d, &cuts, &pool.resident_cuts()).lora_states;
    assert!(
        analytic_pooled * 20.0 <= analytic_eager,
        "analytic pooled {analytic_pooled} vs eager {analytic_eager}"
    );
}

#[test]
fn spilled_clients_round_trip_bit_exactly_under_pressure() {
    let (_d, _cuts, dpool, mut pool) = fleet(50, 2);
    // Train client 7, recording its exact state.
    pool.begin_round(1, 2).unwrap();
    {
        let slot = pool.acquire(7, &dpool).unwrap();
        slot.cs.step = 3;
        slot.ss.step = 5;
        slot.cs.lora.tensors[1].as_f32_mut().unwrap().fill(0.75);
        slot.ss.adam.v[2].as_f32_mut().unwrap().fill(-1.25);
        let _ = slot.it.next_batch();
    }
    let (want_cs, want_ss, want_iter) = {
        let s = pool.resident(7).unwrap();
        let (idx, cur, rng) = s.it.state();
        (s.cs.clone(), s.ss.clone(), (idx.to_vec(), cur, rng))
    };
    // Push 7 out through several generations of churn.
    for round in 2..=6u64 {
        pool.begin_round(round, 2).unwrap();
        pool.acquire(round as usize, &dpool).unwrap();
        pool.acquire(20 + round as usize, &dpool).unwrap();
    }
    assert!(pool.resident(7).is_none());
    pool.begin_round(7, 2).unwrap();
    let slot = pool.acquire(7, &dpool).unwrap();
    assert_eq!(slot.cs.step, want_cs.step);
    assert_eq!(slot.ss.step, want_ss.step);
    assert_eq!(slot.cs.lora.max_abs_diff(&want_cs.lora).unwrap(), 0.0);
    assert_eq!(slot.ss.lora.max_abs_diff(&want_ss.lora).unwrap(), 0.0);
    for (a, b) in slot
        .cs
        .adam
        .m
        .iter()
        .chain(slot.cs.adam.v.iter())
        .chain(slot.ss.adam.m.iter())
        .chain(slot.ss.adam.v.iter())
        .zip(
            want_cs
                .adam
                .m
                .iter()
                .chain(want_cs.adam.v.iter())
                .chain(want_ss.adam.m.iter())
                .chain(want_ss.adam.v.iter()),
        )
    {
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }
    let (idx, cur, rng) = slot.it.state();
    assert_eq!((idx.to_vec(), cur, rng), want_iter);
}

#[test]
fn shared_pool_sessions_have_no_corpus_over_batch_cap() {
    // The old eager partition bailed whenever clients * batch exceeded
    // the corpus; the shared pool only needs the *cohort* covered.
    assert!(data::numeric_feasibility(2_000, 10_000, 8, 32).is_ok());
    assert!(data::numeric_feasibility(2_000, 10_000, 8, 0).is_err());
    // Boundary: cohort * batch exactly equals the corpus.
    assert!(data::numeric_feasibility(256, 10_000, 8, 32).is_ok());
    assert!(data::numeric_feasibility(255, 10_000, 8, 32).is_err());
}
