//! Lossy-channel session properties (EXPERIMENTS.md §Network faults):
//!
//!  1. The all-zero channel constructs nothing — trajectory, round
//!     reports, and checkpoint bytes are identical to a channel-free
//!     run (the eager-twin invariant).
//!  2. An active channel is durable state: sync and async sessions
//!     interrupted mid-run (including with retransmissions in flight
//!     on the event queue) resume bit-identically.
//!  3. The server distinguishes tampering from benign corruption: hash
//!     mismatches retransmit first, and only `tamper_threshold`
//!     consecutive failures escalate to the committee.
//!  4. Error-feedback residuals stay bounded under a sustained-reject
//!     attacker (cleared on quarantine entry and probation
//!     re-admission).
//!
//! Tests skip (with a note) when artifacts/mini is absent so the host-
//! side suite stays green on machines without the AOT toolchain.

use sfl::config::{ChannelConfig, ExperimentConfig};
use sfl::coordinator::{RunResult, Session};
use sfl::runtime::Engine;
use sfl::transport::{CompressKind, QuantKind};
use std::path::{Path, PathBuf};

fn engine() -> Option<Engine> {
    if !Path::new("artifacts/mini/manifest.txt").exists() {
        eprintln!("skipping — artifacts/mini missing; run `make artifacts` first");
        return None;
    }
    let e = Engine::load(Path::new("artifacts"), "mini").expect("loading artifacts/mini");
    if let Err(err) = e.warmup(&[1]) {
        let msg = err.to_string();
        if msg.contains("offline xla stub") {
            eprintln!("skipping — vendored xla stub active; swap in the real `xla` crate (rust/Cargo.toml)");
            return None;
        }
        panic!("warmup(artifacts/mini) failed: {msg}");
    }
    Some(e)
}

fn mini_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::mini();
    c.train.max_rounds = 6;
    c.train.steps_per_round = 2;
    c.train.eval_interval = 2;
    c.train.eval_batches = 4;
    c.train.aggregation_interval = 2;
    c.train.lr = 5e-3;
    c
}

fn lossy_cfg() -> ExperimentConfig {
    let mut c = mini_cfg();
    c.channel = ChannelConfig {
        loss: 0.15,
        corrupt: 0.05,
        dup: 0.05,
        reorder: 0.05,
        burst: 0.3,
        retry_max: 3,
        tamper_threshold: 4,
        ..ChannelConfig::default()
    };
    c
}

fn ckpt_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("sfl_channel_faults_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.sflp"))
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{tag}: round count");
    for (x, y) in a.rounds.iter().zip(b.rounds.iter()) {
        assert_eq!(x.round, y.round, "{tag}: round id");
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{tag}: time @r{}", x.round);
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{tag}: loss @r{}", x.round);
    }
    for (name, sa, sb) in [("acc", &a.acc, &b.acc), ("f1", &a.f1, &b.f1)] {
        assert_eq!(sa.points.len(), sb.points.len(), "{tag}: {name} series length");
        for (x, y) in sa.points.iter().zip(sb.points.iter()) {
            assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{tag}: {name} time");
            assert_eq!(x.value.to_bits(), y.value.to_bits(), "{tag}: {name} value");
        }
    }
    assert_eq!(a.convergence_round, b.convergence_round, "{tag}: convergence round");
    assert_eq!(a.final_acc.to_bits(), b.final_acc.to_bits(), "{tag}: final acc");
    assert_eq!(a.final_f1.to_bits(), b.final_f1.to_bits(), "{tag}: final f1");
    assert_eq!(a.executions, b.executions, "{tag}: executions");
    assert_eq!(a.uplink_bytes, b.uplink_bytes, "{tag}: uplink");
    assert_eq!(a.downlink_bytes, b.downlink_bytes, "{tag}: downlink");
}

fn roundtrip(e: &Engine, cfg: &ExperimentConfig, tag: &str) {
    let mut full = Session::new(e, cfg).unwrap();
    let reference = full.run_to_convergence().unwrap();

    let mut first = Session::new(e, cfg).unwrap();
    for _ in 0..3 {
        first.step_round().unwrap();
    }
    let path = ckpt_path(tag);
    first.checkpoint(&path).unwrap();
    drop(first);

    let mut resumed = Session::resume(e, cfg, &path).unwrap();
    assert_eq!(resumed.round(), 3, "{tag}: resumed at wrong round");
    let result = resumed.run_to_convergence().unwrap();
    assert_bit_identical(&reference, &result, tag);
}

#[test]
fn zero_probability_channel_is_bit_identical_to_channel_free_including_checkpoints() {
    // `--net-loss 0 --net-corrupt 0` must construct no channel at all:
    // identical trajectory, no net block in the reports, and the exact
    // same checkpoint bytes as a run that never heard of [channel].
    let Some(e) = engine() else { return };
    let plain = mini_cfg();
    let mut degenerate = mini_cfg();
    degenerate.channel = ChannelConfig { loss: 0.0, corrupt: 0.0, ..ChannelConfig::default() };
    assert!(!degenerate.channel.is_active());
    let rp = Session::new(&e, &plain).unwrap().run_to_convergence().unwrap();
    let rd = Session::new(&e, &degenerate).unwrap().run_to_convergence().unwrap();
    assert_bit_identical(&rp, &rd, "degenerate-channel");

    let mut sp = Session::new(&e, &plain).unwrap();
    let mut sd = Session::new(&e, &degenerate).unwrap();
    for _ in 0..3 {
        sp.step_round().unwrap();
        let r = sd.step_round().unwrap();
        assert!(r.net.is_none(), "inactive channel must not report net stats");
    }
    let pp = ckpt_path("channel-plain");
    let pd = ckpt_path("channel-degenerate");
    sp.checkpoint(&pp).unwrap();
    sd.checkpoint(&pd).unwrap();
    let bp = std::fs::read(&pp).unwrap();
    let bd = std::fs::read(&pd).unwrap();
    assert!(bp == bd, "degenerate channel checkpoint layout must equal channel-free");
    // The shared layout means a plain checkpoint resumes either way.
    let mut resumed = Session::resume(&e, &degenerate, &pp).unwrap();
    resumed.step_round().unwrap();
}

#[test]
fn lossy_channel_session_resumes_bit_identical() {
    // The channel RNG, Gilbert–Elliott states, sequence numbers, and
    // mismatch counters are durable state — an interrupted lossy run
    // replays its remaining rounds (and retry billing) bit-identically.
    let Some(e) = engine() else { return };
    roundtrip(&e, &lossy_cfg(), "channel-sync");

    // The same protocol with the compressed codec on the wire: payload
    // bits really corrupt, FNV-1a verification really re-runs per
    // retransmission, and error feedback charges once per merge.
    let mut compressed = lossy_cfg();
    compressed.transport.compress = CompressKind::TopK;
    compressed.transport.topk_frac = 0.25;
    compressed.transport.quant = QuantKind::Q8;
    compressed.transport.error_feedback = true;
    roundtrip(&e, &compressed, "channel-transport");
}

#[test]
fn async_channel_mid_retry_checkpoint_resumes_bit_identical() {
    // At 40% loss the event queue routinely holds Timeout/Retransmit
    // events when a merge (and therefore a checkpoint boundary) lands —
    // in-flight retransmissions, backoff draws, and per-client channel
    // state must all survive the round trip.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.asynchrony.enabled = true;
    cfg.asynchrony.buffer_k = 2;
    cfg.asynchrony.staleness_bound = 30.0;
    cfg.asynchrony.staleness_beta = 0.5;
    cfg.channel = ChannelConfig {
        loss: 0.4,
        corrupt: 0.05,
        burst: 0.3,
        retry_max: 3,
        tamper_threshold: 4,
        ..ChannelConfig::default()
    };
    roundtrip(&e, &cfg, "channel-async-midretry");
}

#[test]
fn tampered_sender_escalates_while_benign_corruption_is_retried() {
    // Benign phase: 12% per-delivery corruption with retry budget 5 and
    // threshold 5 — mismatched payloads are retransmitted, nobody is
    // flagged.  Then a real tamperer (post-hash corruption that fails
    // verification on *every* retransmission) crosses the consecutive-
    // mismatch threshold inside one merge and lands in quarantine.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.train.max_rounds = 9;
    cfg.train.aggregation_interval = 1;
    cfg.transport.compress = CompressKind::TopK;
    cfg.transport.topk_frac = 0.25;
    cfg.transport.quant = QuantKind::Q8;
    cfg.transport.error_feedback = true;
    cfg.robust.verify_frac = 0.25;
    cfg.channel = ChannelConfig {
        loss: 0.0,
        corrupt: 0.12,
        retry_max: 5,
        tamper_threshold: 5,
        ..ChannelConfig::default()
    };
    let mut s = Session::new(&e, &cfg).unwrap();
    let mut retries = 0u64;
    for _ in 0..6 {
        let r = s.step_round().unwrap();
        let rb = r.robust.expect("robust stats must stream when the committee is armed");
        assert_eq!(rb.flagged, 0, "benign corruption must never flag a sender");
        assert_eq!(rb.quarantined, 0);
        let net = r.net.expect("active channel must stream net stats");
        retries += net.retries;
    }
    assert!(retries > 0, "12% corruption over 6 full-cohort merges must retransmit");

    // Tamper one payload: with loss 0 every retransmission is delivered
    // and fails verification, so the 5th consecutive mismatch escalates
    // within the same merge.
    s.transport_tamper_next(1);
    let r = s.step_round().unwrap();
    let rb = r.robust.unwrap();
    assert_eq!(rb.flagged, 1, "the tamperer must cross the threshold and be flagged");
    assert_eq!(rb.quarantined, 1, "the tamperer must be quarantined");

    let r2 = s.step_round().unwrap();
    let rb2 = r2.robust.unwrap();
    assert_eq!(rb2.flagged, 0, "honest senders must keep passing after the escalation");
    assert_eq!(rb2.quarantined, 1);
}

#[test]
fn ef_norm_stays_bounded_under_sustained_reject_attacker() {
    // A sender that is rejected round after round (tampered payloads,
    // probation re-admission, tampered again) must not accumulate an
    // unbounded error-feedback residual: EF is cleared on quarantine
    // entry and again on probation re-admission, so the streamed
    // ef_norm stays in the same regime as the honest rounds.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.train.max_rounds = 10;
    cfg.train.aggregation_interval = 1;
    cfg.transport.compress = CompressKind::TopK;
    cfg.transport.topk_frac = 0.25;
    cfg.transport.quant = QuantKind::Q8;
    cfg.transport.error_feedback = true;
    cfg.robust.verify_frac = 0.25;
    cfg.robust.quarantine_ttl = 2;
    let mut s = Session::new(&e, &cfg).unwrap();
    let mut norms: Vec<f64> = Vec::new();
    for _ in 0..cfg.train.max_rounds {
        // Re-tamper every round: whoever encodes first keeps getting
        // rejected, flagged, quarantined, re-admitted, re-flagged.
        s.transport_tamper_next(1);
        let r = s.step_round().unwrap();
        let tp = r.transport.expect("active transport must stream stats");
        assert!(tp.ef_norm.is_finite(), "EF residual must stay finite");
        norms.push(tp.ef_norm);
    }
    let early = norms.iter().take(3).cloned().fold(0.0f64, f64::max);
    let late = norms.iter().skip(3).cloned().fold(0.0f64, f64::max);
    assert!(early > 0.0, "error feedback must be carrying residual mass");
    assert!(
        late <= 10.0 * early,
        "EF residual must stay bounded under sustained rejection \
         (early max {early:.6}, late max {late:.6})"
    );
}

#[test]
fn resume_rejects_changed_channel_config() {
    // The channel knobs are fingerprinted: a different loss rate (or
    // switching the channel off) changes every subsequent dice roll, so
    // resume must refuse rather than silently fork the trajectory.
    let Some(e) = engine() else { return };
    let cfg = lossy_cfg();
    let mut s = Session::new(&e, &cfg).unwrap();
    for _ in 0..2 {
        s.step_round().unwrap();
    }
    let path = ckpt_path("channel-mismatch");
    s.checkpoint(&path).unwrap();
    drop(s);

    let mut relossed = cfg.clone();
    relossed.channel.loss = 0.3;
    assert!(Session::resume(&e, &relossed, &path).is_err());

    let mut rethreshold = cfg.clone();
    rethreshold.channel.tamper_threshold = 1;
    assert!(Session::resume(&e, &rethreshold, &path).is_err());

    let mut off = cfg.clone();
    off.channel = ChannelConfig::default();
    assert!(Session::resume(&e, &off, &path).is_err());

    assert!(Session::resume(&e, &cfg, &path).is_ok(), "unchanged channel config must resume");
}

#[test]
fn adaptive_sanitizer_is_checkpointed_and_fixed_mode_is_untouched() {
    // `--sanitize-mult adaptive` carries an EWMA across rounds — it must
    // survive resume bit-identically — while a fixed multiplier keeps
    // the historical checkpoint key set byte-for-byte.
    let Some(e) = engine() else { return };
    let mut adaptive = mini_cfg();
    adaptive.robust.sanitize = true;
    adaptive.robust.sanitize_adaptive = true;
    adaptive.robust.verify_frac = 0.25;
    roundtrip(&e, &adaptive, "sanitize-adaptive");

    // Fixed-mult twin: flipping adaptive off is a fingerprint change.
    let mut fixed = adaptive.clone();
    fixed.robust.sanitize_adaptive = false;
    let mut s = Session::new(&e, &adaptive).unwrap();
    s.step_round().unwrap();
    let path = ckpt_path("sanitize-mode-mismatch");
    s.checkpoint(&path).unwrap();
    assert!(Session::resume(&e, &fixed, &path).is_err());
}
