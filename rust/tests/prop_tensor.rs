//! Property-based tests for the zero-allocation tensor/LoRA primitives:
//! every `*_into` op must be bit-identical to its allocating
//! counterpart, views must window exactly, and the fused heterogeneous
//! aggregation must equal the join → fedavg → split reference path.
//! Host-side only — no artifacts required.

use sfl::lora::{fedavg, fedavg_into, fedavg_joined_into, AdapterSet};
use sfl::model::ModelDims;
use sfl::tensor::{alloc_count, ops, HostTensor};
use sfl::util::propcheck::{check, gen};

/// `weighted_sum_into` ≡ `weighted_sum`, bit-for-bit, over random
/// shapes, source counts, and weights.
#[test]
fn prop_weighted_sum_into_equals_weighted_sum() {
    check(
        "weighted-sum-into-eq",
        41,
        150,
        |rng| {
            let n = gen::usize_in(rng, 1, 64);
            let srcs = gen::usize_in(rng, 1, 6);
            let tensors: Vec<(f32, Vec<f32>)> = (0..srcs)
                .map(|_| (gen::f64_in(rng, -1.0, 1.0) as f32, gen::vec_f32(rng, n, 2.0)))
                .collect();
            (n, tensors)
        },
        |(n, tensors)| {
            let hosts: Vec<(f32, HostTensor)> = tensors
                .iter()
                .map(|(w, v)| (*w, HostTensor::f32("t", vec![*n], v.clone())))
                .collect();
            let pairs: Vec<(f32, &HostTensor)> = hosts.iter().map(|(w, t)| (*w, t)).collect();
            let reference = ops::weighted_sum(&pairs).unwrap();
            let mut dst = HostTensor::f32("d", vec![*n], vec![f32::NAN; *n]);
            ops::weighted_sum_into(&pairs, &mut dst).unwrap();
            dst.as_f32().unwrap() == reference.as_f32().unwrap()
        },
    );
}

/// View-based split windows the exact bytes the owned split copies, and
/// `split_into` → `join_into` round-trips bit-exactly without a single
/// tensor allocation.
#[test]
fn prop_view_split_join_roundtrip_bit_exact() {
    let dims = ModelDims::mini();
    check(
        "view-split-join-roundtrip",
        43,
        60,
        |rng| {
            let set = AdapterSet::init(&dims, dims.layers, rng.next_u64());
            let k = gen::usize_in(rng, 0, dims.layers);
            (set, k)
        },
        |(set, k)| {
            let (co, so) = set.split_at(*k).unwrap();
            let (cv, sv) = set.split_at_views(*k).unwrap();
            for i in 0..4 {
                if cv.tensors[i].data != co.tensors[i].as_f32().unwrap()
                    || sv.tensors[i].data != so.tensors[i].as_f32().unwrap()
                {
                    return false;
                }
            }
            let mut client = AdapterSet::zeros(&dims, *k);
            let mut server = AdapterSet::zeros(&dims, dims.layers - *k);
            let mut rejoined = AdapterSet::zeros(&dims, dims.layers);
            let before = alloc_count();
            set.split_into(*k, &mut client, &mut server).unwrap();
            AdapterSet::join_into(&client, &server, &mut rejoined).unwrap();
            alloc_count() == before && rejoined.max_abs_diff(set).unwrap() == 0.0
        },
    );
}

/// `fedavg_into` ≡ `fedavg` bit-for-bit for random weights and depths.
#[test]
fn prop_fedavg_into_equals_fedavg() {
    let dims = ModelDims::mini();
    check(
        "fedavg-into-eq",
        47,
        40,
        |rng| {
            let layers = gen::usize_in(rng, 1, dims.layers);
            let a = AdapterSet::init(&dims, layers, rng.next_u64());
            let b = AdapterSet::init(&dims, layers, rng.next_u64());
            let w = gen::f64_in(rng, 0.0, 1.0) as f32;
            (a, b, w)
        },
        |(a, b, w)| {
            let sets = [(*w, a), (1.0 - *w, b)];
            let reference = fedavg(&sets).unwrap();
            let mut dst = AdapterSet::init(&ModelDims::mini(), a.layers, 999);
            fedavg_into(&sets, &mut dst).unwrap();
            dst.max_abs_diff(&reference).unwrap() == 0.0
        },
    );
}

/// The fused heterogeneous aggregation (contributor halves scattered
/// straight into the full-depth aggregate) equals the reference
/// join → fedavg path bit-for-bit, for random per-client cuts, and
/// performs zero tensor allocations.
#[test]
fn prop_fused_aggregation_equals_join_fedavg() {
    let dims = ModelDims::mini();
    check(
        "fused-agg-eq",
        53,
        40,
        |rng| {
            let n_clients = gen::usize_in(rng, 1, 5);
            let halves: Vec<(AdapterSet, AdapterSet)> = (0..n_clients)
                .map(|_| {
                    let k = gen::usize_in(rng, 0, dims.layers);
                    AdapterSet::init(&dims, dims.layers, rng.next_u64())
                        .split_at(k)
                        .unwrap()
                })
                .collect();
            halves
        },
        |halves| {
            let w = 1.0 / halves.len() as f32;
            let joined: Vec<AdapterSet> = halves
                .iter()
                .map(|(c, s)| AdapterSet::join(c, s).unwrap())
                .collect();
            let pairs: Vec<(f32, &AdapterSet)> = joined.iter().map(|j| (w, j)).collect();
            let reference = fedavg(&pairs).unwrap();

            let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> =
                halves.iter().map(|(c, s)| (w, c, s)).collect();
            let mut fused = AdapterSet::zeros(&dims, dims.layers);
            let before = alloc_count();
            fedavg_joined_into(&contribs, &mut fused).unwrap();
            alloc_count() == before && fused.max_abs_diff(&reference).unwrap() == 0.0
        },
    );
}
