//! The paper's §V claims, asserted in *shape* against the analytic
//! models (DESIGN.md experiment index: "§V claims" row).  Absolute
//! numbers are testbed-dependent; ratios and orderings are the claims.

use sfl::config::ExperimentConfig;
use sfl::coordinator::scheduler::*;
use sfl::coordinator::timing;
use sfl::devices::paper_fleet;
use sfl::model::{memory, ModelDims};

fn paper_cuts() -> Vec<usize> {
    paper_fleet().iter().map(|(_, k)| *k).collect()
}

/// Claim: "our scheme can reduce 79% memory footprint" (vs SFL).
#[test]
fn claim_79_percent_memory_reduction_vs_sfl() {
    let dims = ModelDims::bert_base();
    let cuts = paper_cuts();
    let ours = memory::ours_server_memory(&dims, &cuts).total_mb();
    let sfl = memory::sfl_server_memory(&dims, &cuts).total_mb();
    let reduction = 1.0 - ours / sfl;
    // Paper: 79%. Accept 60–90% (shape, not absolutes).
    assert!(
        (0.60..0.90).contains(&reduction),
        "memory reduction vs SFL = {:.1}% (paper: 79%)",
        reduction * 100.0
    );
}

/// Claim: "compared with SL, ... 10% memory cost" (ours ≈ 1.1x SL).
#[test]
fn claim_small_memory_overhead_vs_sl() {
    let dims = ModelDims::bert_base();
    let cuts = paper_cuts();
    let ours = memory::ours_server_memory(&dims, &cuts).total_mb();
    let sl = memory::sl_server_memory(&dims, &cuts).total_mb();
    let overhead = ours / sl - 1.0;
    assert!(
        (-0.05..0.30).contains(&overhead),
        "memory overhead vs SL = {:.1}% (paper: ~10%)",
        overhead * 100.0
    );
}

/// Claim: "reduces the training time by 40% at the 10% memory cost"
/// (vs SL) — per-round time ratio under the timing model.
#[test]
fn claim_time_reduction_vs_sl() {
    let cfg = ExperimentConfig::paper();
    let dims = cfg.timing_dims();
    let cuts = cfg.resolve_cuts();
    let steps = 4usize;
    let (step, _) =
        timing::ours_step(&dims, &cfg.clients, &cuts, &cfg.server, &mut ProposedScheduler);
    let ours_round = steps as f64 * step;
    let sl_round = timing::sl_round(&dims, &cfg.clients, &cuts, &cfg.server, steps);
    let reduction = 1.0 - ours_round / sl_round;
    // Paper end-to-end: 41%, but that folds in SL converging in fewer
    // rounds (89 vs 180). The *per-round* ratio in Table I is
    // 644s/186s ⇒ a 71% per-round reduction; accept 55–90%. The
    // end-to-end number (with the convergence detector) is produced by
    // benches/table1.rs.
    assert!(
        (0.55..0.90).contains(&reduction),
        "per-round time reduction vs SL = {:.1}% (paper per-round: 71%)",
        reduction * 100.0
    );
}

/// Claim: "reduces ... 6% of training time" vs SFL.
#[test]
fn claim_time_reduction_vs_sfl() {
    let cfg = ExperimentConfig::paper();
    let dims = cfg.timing_dims();
    let cuts = cfg.resolve_cuts();
    let (ours, _) =
        timing::ours_step(&dims, &cfg.clients, &cuts, &cfg.server, &mut ProposedScheduler);
    let (sfl, _) = timing::sfl_step(&dims, &cfg.clients, &cuts, &cfg.server);
    let reduction = 1.0 - ours / sfl;
    // Paper: 6.1%. Accept 1–30%.
    assert!(
        (0.01..0.30).contains(&reduction),
        "time reduction vs SFL = {:.1}% (paper: 6.1%)",
        reduction * 100.0
    );
}

/// Claim (Fig. 2c): proposed scheduling beats WF and FIFO; quantified on
/// a doubled fleet where arrival diversity separates the policies.
#[test]
fn claim_scheduler_beats_baselines() {
    let cfg = ExperimentConfig::paper();
    let dims = cfg.timing_dims();
    let mut clients = Vec::new();
    let mut cuts = Vec::new();
    for _ in 0..2 {
        for (d, k) in paper_fleet() {
            clients.push(sfl::config::ClientConfig {
                device: d,
                cut: Some(k),
                link: sfl::net::Link::paper_default(),
            });
            cuts.push(k);
        }
    }
    let t = |s: &mut dyn Scheduler| timing::ours_step(&dims, &clients, &cuts, &cfg.server, s).0;
    let proposed = t(&mut ProposedScheduler);
    let fifo = t(&mut FifoScheduler);
    let wf = t(&mut WorkloadFirstScheduler);
    assert!(proposed <= wf + 1e-12, "proposed {proposed} vs wf {wf}");
    assert!(proposed <= fifo + 1e-12, "proposed {proposed} vs fifo {fifo}");
    // And strictly better than at least one baseline (paper: 5.5%/6.2%).
    assert!(
        proposed < wf - 1e-9 || proposed < fifo - 1e-9,
        "proposed must strictly beat a baseline: p={proposed} wf={wf} fifo={fifo}"
    );
}

/// Claim (§I): the server-side memory of Ours stays nearly flat as the
/// fleet grows, while SFL scales linearly — the scalability argument.
#[test]
fn claim_scalability_in_client_count() {
    let dims = ModelDims::bert_base();
    let base_cuts = paper_cuts();
    let mut big_cuts = base_cuts.clone();
    for _ in 0..3 {
        big_cuts.extend_from_slice(&base_cuts);
    }
    let ours_growth = memory::ours_server_memory(&dims, &big_cuts).total_mb()
        / memory::ours_server_memory(&dims, &base_cuts).total_mb();
    let sfl_growth = memory::sfl_server_memory(&dims, &big_cuts).total_mb()
        / memory::sfl_server_memory(&dims, &base_cuts).total_mb();
    assert!(ours_growth < 1.5, "ours grew {ours_growth:.2}x for 4x clients");
    assert!(sfl_growth > 3.0, "sfl should scale ~linearly, got {sfl_growth:.2}x");
}

/// Table I absolute ballpark: the accountant lands within ~35% of the
/// paper's measured MBs for all three schemes (BERT-base, fp32).
#[test]
fn claim_table1_absolute_memory_ballpark() {
    let dims = ModelDims::bert_base();
    let cuts = paper_cuts();
    let sl = memory::sl_server_memory(&dims, &cuts).total_mb();
    let sfl = memory::sfl_server_memory(&dims, &cuts).total_mb();
    let ours = memory::ours_server_memory(&dims, &cuts).total_mb();
    let within = |got: f64, paper: f64| (got / paper - 1.0).abs() < 0.35;
    assert!(within(sl, 1346.85), "SL {sl:.1} vs paper 1346.85");
    assert!(within(sfl, 7327.90), "SFL {sfl:.1} vs paper 7327.90");
    assert!(within(ours, 1482.63), "Ours {ours:.1} vs paper 1482.63");
}
