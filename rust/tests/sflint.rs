//! Fixture tests for the sflint analyzer: one positive + one negative
//! case per rule (R1–R5), pragma suppression, and the baseline
//! round-trip.  Fixtures are written in the idiom of the real modules
//! they model (the R2 fixture mirrors `events/staleness.rs`) so the
//! rules are exercised on realistic shapes, not toy strings.

use sfl::lint::{analyze_source, analyze_tree, load_baseline, split_baselined, Finding};

fn rules_hit(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// R1 — determinism.
// ---------------------------------------------------------------------------

const R1_FIXTURE: &str = r#"
use std::collections::HashMap;
use std::time::Instant;

pub fn slowest(times: &HashMap<usize, f64>) -> f64 {
    let t0 = Instant::now();
    let mut worst = 0.0;
    for (_, v) in times {
        if *v > worst {
            worst = *v;
        }
    }
    let _ = t0.elapsed();
    worst
}
"#;

#[test]
fn r1_flags_wall_clock_and_hash_iteration() {
    let findings = analyze_source("coordinator/timing.rs", R1_FIXTURE);
    let r1: Vec<&Finding> = findings.iter().filter(|f| f.rule == "R1").collect();
    assert!(
        r1.iter().any(|f| f.msg.contains("Instant")),
        "Instant must be flagged: {findings:?}"
    );
    assert!(
        r1.iter().any(|f| f.msg.contains("iteration order")),
        "HashMap iteration must be flagged: {findings:?}"
    );
}

#[test]
fn r1_exempts_the_clock_and_rng_modules() {
    for rel in ["simclock/mod.rs", "simclock/source.rs", "tensor/rng.rs"] {
        let findings = analyze_source(rel, R1_FIXTURE);
        assert!(
            findings.iter().all(|f| f.rule != "R1"),
            "{rel} is exempt from R1, got {findings:?}"
        );
    }
}

#[test]
fn r1_clean_deterministic_code_passes() {
    let src = "use std::collections::BTreeMap;\n\
               pub fn f(m: &BTreeMap<usize, f64>) -> usize {\n    m.len()\n}\n";
    assert!(analyze_source("coordinator/timing.rs", src).is_empty());
}

// ---------------------------------------------------------------------------
// R2 — checkpoint coverage.  Modeled on events/staleness.rs: a version
// vector with state()/restore_state() serializers and one field the
// serializers forgot.
// ---------------------------------------------------------------------------

const R2_FIXTURE: &str = r#"
pub struct VersionVector {
    model: u64,
    clients: Vec<u64>,
    inflight: Vec<bool>,
}

impl VersionVector {
    pub fn state(&self) -> Vec<u64> {
        let mut words = Vec::with_capacity(1 + self.clients.len());
        words.push(self.model);
        words.extend_from_slice(&self.clients);
        words
    }

    pub fn restore_state(&mut self, words: &[u64]) {
        self.model = words[0];
        self.clients.copy_from_slice(&words[1..]);
    }
}
"#;

#[test]
fn r2_catches_the_un_checkpointed_field() {
    let findings = analyze_source("events/staleness.rs", R2_FIXTURE);
    assert_eq!(rules_hit(&findings), vec!["R2"], "{findings:?}");
    assert!(findings[0].msg.contains("`inflight`"), "{findings:?}");
    assert!(findings[0].msg.contains("VersionVector"), "{findings:?}");
}

#[test]
fn r2_passes_once_every_field_is_serialized() {
    let fixed = R2_FIXTURE.replace(
        "self.clients.copy_from_slice(&words[1..]);",
        "self.clients.copy_from_slice(&words[1..]);\n        self.inflight.clear();",
    );
    assert!(analyze_source("events/staleness.rs", &fixed).is_empty());
}

#[test]
fn r2_ignores_structs_without_serializers() {
    let src = "pub struct Snapshot {\n    pub mfu: f64,\n    pub link: f64,\n}\n";
    assert!(analyze_source("trace/view.rs", src).is_empty());
}

// ---------------------------------------------------------------------------
// R3 — config symmetry.
// ---------------------------------------------------------------------------

const R3_FIXTURE: &str = r#"
pub struct TrainConfig {
    pub lr: f64,
    pub warmup: f32,
}

pub struct ExperimentConfig {
    pub train: TrainConfig,
}

impl ExperimentConfig {
    pub fn to_kv(&self) -> String {
        format!("{}", self.train.lr)
    }

    pub fn validate(&self) -> bool {
        self.train.lr.is_finite()
    }
}

pub fn from_kv_file(text: &str) -> f64 {
    let lr = text.len() as f64;
    lr
}
"#;

#[test]
fn r3_flags_a_field_missing_from_all_three_surfaces() {
    let findings = analyze_source("config/mod.rs", R3_FIXTURE);
    assert_eq!(rules_hit(&findings), vec!["R3", "R3", "R3"], "{findings:?}");
    assert!(findings.iter().all(|f| f.msg.contains("`train.warmup`")), "{findings:?}");
    assert!(findings.iter().any(|f| f.msg.contains("missing from to_kv")));
    assert!(findings.iter().any(|f| f.msg.contains("missing from the kv parser")));
    assert!(findings.iter().any(|f| f.msg.contains("missing from validate()")));
}

#[test]
fn r3_passes_when_every_surface_names_the_field() {
    let fixed = R3_FIXTURE
        .replace(
            "format!(\"{}\", self.train.lr)",
            "format!(\"{} {}\", self.train.lr, self.train.warmup)",
        )
        .replace(
            "self.train.lr.is_finite()",
            "self.train.lr.is_finite() && self.train.warmup > 0.0",
        )
        .replace(
            "let lr = text.len() as f64;",
            "let lr = text.len() as f64;\n    let warmup = 0.0f32;\n    let _ = warmup;",
        );
    assert!(analyze_source("config/mod.rs", &fixed).is_empty());
}

#[test]
fn r3_is_silent_outside_the_experiment_config_file() {
    let src = "pub struct TrainConfig {\n    pub lr: f64,\n}\n";
    assert!(analyze_source("coordinator/lr.rs", src).is_empty());
}

// ---------------------------------------------------------------------------
// R4 — panic discipline.
// ---------------------------------------------------------------------------

#[test]
fn r4_flags_unwrap_outside_tests_only() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let findings = analyze_source("util/mod.rs", src);
    assert_eq!(rules_hit(&findings), vec!["R4"], "{findings:?}");

    let test_src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
                    Some(1u32).unwrap();\n    }\n}\n";
    assert!(analyze_source("util/mod.rs", test_src).is_empty());
}

#[test]
fn r4_flags_panic_macros_but_not_lookalikes() {
    let src = "pub fn f() {\n    panic!(\"boom\");\n}\n";
    assert_eq!(rules_hit(&analyze_source("util/mod.rs", src)), vec!["R4"]);
    let ok = "pub fn f(s: &str) -> bool {\n    s.contains(\"panic!(\")\n}\n";
    assert!(analyze_source("util/mod.rs", ok).is_empty(), "string contents are masked");
}

// ---------------------------------------------------------------------------
// R5 — float comparison order.
// ---------------------------------------------------------------------------

#[test]
fn r5_flags_partial_cmp_and_accepts_total_cmp() {
    let bad = "pub fn sort(v: &mut [f64]) {\n    \
               v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
    let findings = analyze_source("metrics/mod.rs", bad);
    assert!(rules_hit(&findings).contains(&"R5"), "{findings:?}");

    let good = "pub fn sort(v: &mut [f64]) {\n    v.sort_by(|a, b| a.total_cmp(b));\n}\n";
    assert!(analyze_source("metrics/mod.rs", good).is_empty());
}

#[test]
fn r5_exempts_partial_cmp_trait_impls() {
    let src = "impl PartialOrd for Wrapper {\n    \
               fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {\n        \
               Some(self.0.total_cmp(&other.0))\n    }\n}\n";
    assert!(analyze_source("metrics/mod.rs", src).is_empty());
}

// ---------------------------------------------------------------------------
// Pragmas.
// ---------------------------------------------------------------------------

#[test]
fn pragma_with_reason_suppresses_by_name_or_id() {
    for tag in ["panic-discipline", "R4"] {
        let src = format!(
            "pub fn f(x: Option<u32>) -> u32 {{\n    \
             // sflint:allow({tag}, fixture exercises the pragma path)\n    x.unwrap()\n}}\n"
        );
        assert!(analyze_source("util/mod.rs", &src).is_empty(), "tag {tag}");
    }
}

#[test]
fn pragma_without_reason_is_ignored() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n    \
               // sflint:allow(panic-discipline)\n    x.unwrap()\n}\n";
    assert_eq!(rules_hit(&analyze_source("util/mod.rs", src)), vec!["R4"]);
}

#[test]
fn pragma_only_covers_its_own_rule() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n    \
               // sflint:allow(determinism, wrong rule for this line)\n    x.unwrap()\n}\n";
    assert_eq!(rules_hit(&analyze_source("util/mod.rs", src)), vec!["R4"]);
}

// ---------------------------------------------------------------------------
// Baseline round-trip + tree walk.
// ---------------------------------------------------------------------------

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("sflint-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn baseline_grandfathers_known_findings_across_line_drift() {
    let findings = analyze_source("events/staleness.rs", R2_FIXTURE);
    assert_eq!(findings.len(), 1);

    let dir = temp_dir("baseline");
    let path = dir.join("baseline.jsonl");
    let jsonl: String = findings.iter().map(|f| f.to_json() + "\n").collect();
    std::fs::write(&path, jsonl).unwrap();

    let baseline = load_baseline(&path).unwrap();
    assert_eq!(baseline.len(), 1);

    // The same finding on a later line (comment shifts everything down)
    // is still absorbed: baseline identity ignores line numbers.
    let shifted = format!("// leading comment\n//\n//\n{R2_FIXTURE}");
    let later = analyze_source("events/staleness.rs", &shifted);
    assert_eq!(later.len(), 1);
    assert_ne!(later[0].line, findings[0].line);
    let (fresh, old) = split_baselined(later, &baseline);
    assert!(fresh.is_empty(), "{fresh:?}");
    assert_eq!(old.len(), 1);

    // A different finding is NOT absorbed.
    let other =
        analyze_source("util/mod.rs", "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n");
    let (fresh, old) = split_baselined(other, &baseline);
    assert_eq!(fresh.len(), 1);
    assert!(old.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_baseline_is_rejected() {
    let dir = temp_dir("malformed");
    let path = dir.join("baseline.jsonl");
    std::fs::write(&path, "not json at all\n").unwrap();
    assert!(load_baseline(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn analyze_tree_walks_nested_files_with_relative_paths() {
    let dir = temp_dir("tree");
    std::fs::create_dir_all(dir.join("util")).unwrap();
    std::fs::write(
        dir.join("util").join("x.rs"),
        "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
    )
    .unwrap();
    std::fs::write(dir.join("clean.rs"), "pub fn g() -> u32 {\n    1\n}\n").unwrap();
    std::fs::write(dir.join("notes.txt"), "not rust\n").unwrap();

    let findings = analyze_tree(&dir).unwrap();
    assert_eq!(rules_hit(&findings), vec!["R4"], "{findings:?}");
    assert_eq!(findings[0].path, "util/x.rs", "paths are /-separated and root-relative");
    std::fs::remove_dir_all(&dir).ok();
}
