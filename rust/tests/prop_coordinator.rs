//! Property-based tests on coordinator invariants (routing/scheduling,
//! aggregation, queueing) using the in-tree propcheck runner.

use sfl::coordinator::scheduler::*;
use sfl::faults::differs;
use sfl::lora::{
    clipped_fedavg_joined_into, fedavg, fedavg_joined_into, trimmed_fedavg_joined_into, AdapterSet,
};
use sfl::model::ModelDims;
use sfl::simclock::SequentialResource;
use sfl::tensor::rng::Rng;
use sfl::util::propcheck::{check, gen};

fn random_jobs(rng: &mut Rng, n: usize) -> Vec<JobInfo> {
    (0..n)
        .map(|i| JobInfo {
            client: i,
            arrival: gen::f64_in(rng, 0.0, 5.0),
            server_time: gen::f64_in(rng, 0.1, 4.0),
            client_bwd_time: gen::f64_in(rng, 0.1, 8.0),
            bwd_comm_time: gen::f64_in(rng, 0.0, 0.5),
            n_client_adapters: gen::usize_in(rng, 1, 6),
            compute_capability: gen::f64_in(rng, 0.2, 4.0),
        })
        .collect()
}

/// [`random_jobs`] with dropout-round id labels: strictly increasing
/// but non-contiguous global client ids (survivors of a bigger fleet).
fn random_dropout_jobs(rng: &mut Rng, n: usize) -> Vec<JobInfo> {
    let mut jobs = random_jobs(rng, n);
    let mut id = 0usize;
    for j in &mut jobs {
        id += gen::usize_in(rng, 1, 5);
        j.client = id;
    }
    jobs
}

/// Every scheduler always emits a permutation of the *job indices* —
/// even when the client id labels are non-contiguous (dropout rounds),
/// which is exactly where the old return-ids contract went wrong.
#[test]
fn prop_schedulers_emit_permutations() {
    for kind in ["proposed", "fifo", "wf", "random"] {
        check(
            &format!("{kind}-is-permutation"),
            11,
            200,
            |rng| {
                let n = gen::usize_in(rng, 1, 12);
                if gen::usize_in(rng, 0, 1) == 0 {
                    random_jobs(rng, n)
                } else {
                    random_dropout_jobs(rng, n)
                }
            },
            |jobs| {
                let mut s: Box<dyn Scheduler> = match kind {
                    "proposed" => Box::new(ProposedScheduler),
                    "fifo" => Box::new(FifoScheduler),
                    "wf" => Box::new(WorkloadFirstScheduler),
                    _ => Box::new(RandomScheduler::new(3)),
                };
                let mut order = s.order(jobs);
                order.sort_unstable();
                order == (0..jobs.len()).collect::<Vec<_>>()
            },
        );
    }
}

/// Fleet-sweep optimality envelope: on seeded random fleets (n ≤ 7,
/// dropout-shaped ids) the greedy Alg. 2 schedule is a valid index
/// permutation, never beats the brute-force optimum, and stays within a
/// bounded factor of it.  The 3× envelope is a sanity bound from
/// m ≤ max-arrival + Σ server + max-tail vs. the optimum's lower
/// bounds, not a tight guarantee.
#[test]
fn prop_proposed_bounded_ratio_vs_brute_force_under_dropout() {
    check(
        "proposed-bounded-ratio-dropout",
        43,
        80,
        |rng| { let n = gen::usize_in(rng, 2, 7); random_dropout_jobs(rng, n) },
        |jobs| {
            let mut order = ProposedScheduler.order(jobs);
            let m = makespan(jobs, &order);
            let (_, best) = brute_force_best(jobs);
            order.sort_unstable();
            order == (0..jobs.len()).collect::<Vec<_>>()
                && m >= best - 1e-9
                && m <= 3.0 * best + 1e-9
        },
    );
}

/// The schedule path is allocation-free at fleet scale: repeated
/// order_into + makespan over 10k jobs allocate zero HostTensors and
/// never regrow the reused order buffer (extends the PR-1 steady-state
/// allocation gate to scheduling).
#[test]
fn prop_schedule_path_is_allocation_free_at_10k() {
    let mut rng = Rng::new(47);
    let jobs = random_jobs(&mut rng, 10_000);
    let mut buf: Vec<usize> = Vec::new();
    for kind in [
        sfl::config::SchedulerKind::Proposed,
        sfl::config::SchedulerKind::Fifo,
        sfl::config::SchedulerKind::WorkloadFirst,
        sfl::config::SchedulerKind::Random,
    ] {
        let mut s = make_scheduler(kind, 9);
        s.order_into(&jobs, &mut buf); // warm-up sizes the buffer
        let (cap, ptr) = (buf.capacity(), buf.as_ptr());
        let before = sfl::tensor::alloc_count();
        for _ in 0..5 {
            s.order_into(&jobs, &mut buf);
            std::hint::black_box(makespan(&jobs, &buf));
        }
        assert_eq!(sfl::tensor::alloc_count(), before, "{}: allocated tensors", s.name());
        assert_eq!(buf.capacity(), cap, "{}: buffer regrew", s.name());
        assert_eq!(buf.as_ptr(), ptr, "{}: buffer reallocated", s.name());
    }
}

/// Makespan depends only on the *sequence of jobs processed*, never on
/// their client-id labels or slice positions: relabeling ids is a
/// no-op, and permuting the slice is exactly compensated by remapping
/// the order's indices.
#[test]
fn prop_makespan_depends_only_on_processed_sequence() {
    check(
        "makespan-sequence-invariant",
        13,
        150,
        |rng| {
            let n = gen::usize_in(rng, 2, 8);
            let jobs = random_jobs(rng, n);
            let swap = (gen::usize_in(rng, 0, n - 1), gen::usize_in(rng, 0, n - 1));
            (jobs, swap)
        },
        |(jobs, (i, j))| {
            let order: Vec<usize> = (0..jobs.len()).collect();
            let reference = makespan(jobs, &order);
            // Relabeling the client ids changes nothing.
            let mut relabeled = jobs.clone();
            for (x, jb) in relabeled.iter_mut().enumerate() {
                jb.client = 100 + 7 * x;
            }
            if (makespan(&relabeled, &order) - reference).abs() > 1e-9 {
                return false;
            }
            // Swapping two slice positions + remapping the order is a no-op.
            let mut shuffled = jobs.clone();
            shuffled.swap(*i, *j);
            let remapped: Vec<usize> = order
                .iter()
                .map(|&x| {
                    if x == *i {
                        *j
                    } else if x == *j {
                        *i
                    } else {
                        x
                    }
                })
                .collect();
            (makespan(&shuffled, &remapped) - reference).abs() < 1e-9
        },
    );
}

/// The proposed greedy never loses to random ordering *on average*, and
/// never beats the brute-force optimum.
#[test]
fn prop_proposed_bounded_by_optimum() {
    check(
        "proposed-vs-optimum",
        17,
        60,
        |rng| { let n = gen::usize_in(rng, 2, 6); random_jobs(rng, n) },
        |jobs| {
            let order = ProposedScheduler.order(jobs);
            let m = makespan(jobs, &order);
            let (_, best) = brute_force_best(jobs);
            m >= best - 1e-9
        },
    );
}

/// With zero arrivals and equal server times, the greedy N_c/C rule *is*
/// optimal when backward time is proportional to N_c/C (the paper's
/// modeling assumption in §IV).
#[test]
fn prop_proposed_optimal_under_paper_assumptions() {
    check(
        "proposed-optimal-paper-model",
        19,
        60,
        |rng| {
            let n = gen::usize_in(rng, 2, 6);
            let ts = gen::f64_in(rng, 0.5, 2.0);
            (0..n)
                .map(|i| {
                    let nc = gen::usize_in(rng, 1, 6);
                    let c = gen::f64_in(rng, 0.2, 4.0);
                    JobInfo {
                        client: i,
                        arrival: 0.0,
                        server_time: ts,
                        client_bwd_time: nc as f64 / c,
                        bwd_comm_time: 0.0,
                        n_client_adapters: nc,
                        compute_capability: c,
                    }
                })
                .collect::<Vec<_>>()
        },
        |jobs| {
            let order = ProposedScheduler.order(jobs);
            let m = makespan(jobs, &order);
            let (_, best) = brute_force_best(jobs);
            (m - best).abs() < 1e-9
        },
    );
}

/// FedAvg with weights (w, 1-w) is a convex combination: every element
/// of the aggregate lies between the per-client extremes.
#[test]
fn prop_fedavg_convexity() {
    let dims = ModelDims::mini();
    check(
        "fedavg-convex",
        23,
        40,
        |rng| {
            let a = AdapterSet::init(&dims, 2, rng.next_u64());
            let b = AdapterSet::init(&dims, 2, rng.next_u64());
            let w = gen::f64_in(rng, 0.0, 1.0) as f32;
            (a, b, w)
        },
        |(a, b, w)| {
            let agg = fedavg(&[(*w, a), (1.0 - *w, b)]).unwrap();
            for i in 0..4 {
                let av = a.tensors[i].as_f32().unwrap();
                let bv = b.tensors[i].as_f32().unwrap();
                let gv = agg.tensors[i].as_f32().unwrap();
                for ((x, y), g) in av.iter().zip(bv).zip(gv) {
                    let (lo, hi) = if x < y { (x, y) } else { (y, x) };
                    if *g < lo - 1e-5 || *g > hi + 1e-5 {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// split_at(k) then join is the identity for any valid k.
#[test]
fn prop_split_join_identity() {
    let dims = ModelDims::mini();
    check(
        "split-join-id",
        29,
        40,
        |rng| {
            let set = AdapterSet::init(&dims, dims.layers, rng.next_u64());
            let k = gen::usize_in(rng, 0, dims.layers);
            (set, k)
        },
        |(set, k)| {
            let (c, s) = set.split_at(*k).unwrap();
            let joined = AdapterSet::join(&c, &s).unwrap();
            joined.max_abs_diff(set).unwrap() == 0.0
        },
    );
}

/// The robust merge kernels at their degenerate settings are exact
/// no-ops: `trim == 0` and a non-finite clip threshold both delegate to
/// `fedavg_joined_into` and must be *bit*-identical to it (the "robust
/// options off ⇒ today's trajectory" guarantee, at the kernel level).
#[test]
fn prop_robust_kernels_degenerate_to_fedavg_bitwise() {
    let dims = ModelDims::mini();
    check(
        "robust-kernels-degenerate",
        41,
        30,
        |rng| {
            let n = gen::usize_in(rng, 1, 5);
            let k = gen::usize_in(rng, 0, dims.layers);
            let sets: Vec<AdapterSet> =
                (0..n).map(|_| AdapterSet::init(&dims, dims.layers, rng.next_u64())).collect();
            let baseline = AdapterSet::init(&dims, dims.layers, rng.next_u64());
            (sets, baseline, k)
        },
        |(sets, baseline, k)| {
            let halves: Vec<(AdapterSet, AdapterSet)> =
                sets.iter().map(|s| s.split_at(*k).unwrap()).collect();
            let w = 1.0 / sets.len() as f32;
            let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> =
                halves.iter().map(|(c, s)| (w, c, s)).collect();
            let mut plain = AdapterSet::zeros(&dims, dims.layers);
            fedavg_joined_into(&contribs, &mut plain).unwrap();
            let mut trimmed = AdapterSet::zeros(&dims, dims.layers);
            let mut col: Vec<(f32, f32)> = Vec::new();
            trimmed_fedavg_joined_into(&contribs, 0, &mut col, &mut trimmed).unwrap();
            let mut clipped = AdapterSet::zeros(&dims, dims.layers);
            let n_clipped =
                clipped_fedavg_joined_into(&contribs, baseline, f64::INFINITY, &mut clipped)
                    .unwrap();
            n_clipped == 0
                && !differs(&plain, &trimmed).unwrap()
                && !differs(&plain, &clipped).unwrap()
        },
    );
}

/// Sequential resource: completion times are non-decreasing in admission
/// order and no job starts before its arrival (eq. 11 sanity).
#[test]
fn prop_sequential_resource_ordering() {
    check(
        "seq-resource-order",
        31,
        150,
        |rng| {
            let n = gen::usize_in(rng, 1, 10);
            (0..n)
                .map(|_| (gen::f64_in(rng, 0.0, 10.0), gen::f64_in(rng, 0.01, 3.0)))
                .collect::<Vec<_>>()
        },
        |jobs| {
            let mut r = SequentialResource::default();
            let mut last_finish = 0.0f64;
            for &(arrival, dur) in jobs {
                let (start, finish) = r.admit(arrival, dur);
                if start < arrival - 1e-12 || finish < last_finish - 1e-12 {
                    return false;
                }
                last_finish = finish;
            }
            true
        },
    );
}

/// Aggregate-then-split == split-then-aggregate for any cut and weights
/// (linearity — the identity that makes heterogeneous aggregation sound).
#[test]
fn prop_aggregation_split_commute() {
    let dims = ModelDims::mini();
    check(
        "agg-split-commute",
        37,
        30,
        |rng| {
            let u1 = AdapterSet::init(&dims, dims.layers, rng.next_u64());
            let u2 = AdapterSet::init(&dims, dims.layers, rng.next_u64());
            let w = gen::f64_in(rng, 0.05, 0.95) as f32;
            let k = gen::usize_in(rng, 1, dims.layers - 1);
            (u1, u2, w, k)
        },
        |(u1, u2, w, k)| {
            let agg = fedavg(&[(*w, u1), (1.0 - *w, u2)]).unwrap();
            let (ac, as_) = agg.split_at(*k).unwrap();
            let (c1, s1) = u1.split_at(*k).unwrap();
            let (c2, s2) = u2.split_at(*k).unwrap();
            let ac2 = fedavg(&[(*w, &c1), (1.0 - *w, &c2)]).unwrap();
            let as2 = fedavg(&[(*w, &s1), (1.0 - *w, &s2)]).unwrap();
            ac.max_abs_diff(&ac2).unwrap() < 1e-6 && as_.max_abs_diff(&as2).unwrap() < 1e-6
        },
    );
}
