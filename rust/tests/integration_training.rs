//! Coordinator integration: full scheme runs over the mini artifacts.
//! One engine is shared; each sub-test uses few rounds to stay fast.
//!
//! Tests skip (with a note) when artifacts/mini is absent so the host-
//! side suite stays green on machines without the AOT toolchain.

use sfl::config::{ExperimentConfig, SchedulerKind, SchemeKind};
use sfl::coordinator::scheduler::{makespan, RandomScheduler, Scheduler};
use sfl::coordinator::{timing, Session};
use sfl::runtime::Engine;
use std::path::Path;

fn engine() -> Option<Engine> {
    if !Path::new("artifacts/mini/manifest.txt").exists() {
        eprintln!("skipping — artifacts/mini missing; run `make artifacts` first");
        return None;
    }
    let e = Engine::load(Path::new("artifacts"), "mini").expect("loading artifacts/mini");
    // The vendored xla stub can load artifacts but not compile them —
    // skip (rather than fail) until the real `xla` crate is swapped in.
    if let Err(err) = e.warmup(&[1]) {
        let msg = err.to_string();
        if msg.contains("offline xla stub") {
            eprintln!("skipping — vendored xla stub active; swap in the real `xla` crate (rust/Cargo.toml)");
            return None;
        }
        panic!("warmup(artifacts/mini) failed: {msg}");
    }
    Some(e)
}

fn mini_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::mini();
    c.train.max_rounds = 6;
    c.train.steps_per_round = 2;
    c.train.eval_interval = 2;
    c.train.eval_batches = 4;
    c.train.aggregation_interval = 2;
    c.train.lr = 5e-3;
    c
}

/// Reset this thread's steady-state counters and prove each is live
/// with a one-event canary.  A dead or poisoned counter would let the
/// zero-alloc / zero-clone gates below pass vacuously, so every gate
/// calls this before measuring.
fn assert_counters_live(cfg: &ExperimentConfig) {
    sfl::tensor::reset_alloc_count();
    let canary = sfl::tensor::HostTensor::zeros("counter_canary", vec![1]);
    assert_eq!(sfl::tensor::alloc_count(), 1, "tensor alloc counter is not live");
    drop(canary);
    sfl::tensor::reset_alloc_count();
    assert_eq!(sfl::tensor::alloc_count(), 0, "tensor alloc counter did not reset");

    sfl::config::reset_client_clone_count();
    let clone = cfg.clients[0].clone();
    assert_eq!(sfl::config::client_clone_count(), 1, "client clone counter is not live");
    drop(clone);
    sfl::config::reset_client_clone_count();
    assert_eq!(sfl::config::client_clone_count(), 0, "client clone counter did not reset");
}

#[test]
fn ours_trains_and_reports() {
    let Some(e) = engine() else { return };
    let cfg = mini_cfg();
    let mut t = Session::new(&e, &cfg).unwrap();
    assert_eq!(t.cuts(), &[1, 1, 2, 2, 3, 3]);
    let r = t.run_to_convergence().unwrap();

    assert_eq!(r.scheme, SchemeKind::Ours);
    assert_eq!(r.rounds.len(), 6);
    // Virtual time advances monotonically.
    for w in r.rounds.windows(2) {
        assert!(w[1].sim_time > w[0].sim_time);
    }
    // Loss trends down (first vs last round mean).
    let first = r.rounds.first().unwrap().mean_loss;
    let last = r.rounds.last().unwrap().mean_loss;
    assert!(last < first, "loss did not improve: {first} -> {last}");
    // Eval series populated at the eval interval.
    assert_eq!(r.acc.points.len(), 3);
    assert!(r.final_acc > 0.0);
    // Adapter switching happened (sequential server, 6 clients).
    assert!(r.adapter_switches >= 6);
    // Memory model: Ours uses the ours accountant.
    assert!(r.memory_mb > 0.0);
}

#[test]
fn steady_state_is_host_tensor_allocation_free() {
    // The tentpole invariant: after round 1, training rounds (inner
    // loop + aggregation + evaluation) perform zero HostTensor
    // allocations.  Two runs that differ only in round count must
    // therefore allocate exactly the same number of tensors.
    let Some(e) = engine() else { return };
    assert_counters_live(&mini_cfg());
    let allocs_for = |rounds: usize| {
        let mut cfg = mini_cfg();
        cfg.train.max_rounds = rounds;
        let mut t = Session::new(&e, &cfg).unwrap();
        let before = sfl::tensor::alloc_count();
        t.run_to_convergence().unwrap();
        sfl::tensor::alloc_count() - before
    };
    let short = allocs_for(2);
    let long = allocs_for(4);
    assert_eq!(
        long, short,
        "rounds 3-4 allocated {} extra HostTensors (steady state must be allocation-free)",
        long - short
    );
}

#[test]
fn sl_steady_state_is_host_tensor_allocation_free() {
    // SL now runs on the same in-place primitives as the parallel
    // schemes: the relay copies into reused per-client buffers
    // (split_into / copy_from / in-place optimizer reset) and joins
    // back with join_into — zero HostTensor allocations per round.
    let Some(e) = engine() else { return };
    assert_counters_live(&mini_cfg());
    let allocs_for = |rounds: usize| {
        let mut cfg = mini_cfg();
        cfg.scheme = SchemeKind::Sl;
        cfg.train.max_rounds = rounds;
        let mut t = Session::new(&e, &cfg).unwrap();
        let before = sfl::tensor::alloc_count();
        t.run_to_convergence().unwrap();
        sfl::tensor::alloc_count() - before
    };
    let short = allocs_for(2);
    let long = allocs_for(4);
    assert_eq!(
        long, short,
        "SL rounds 3-4 allocated {} extra HostTensors (steady state must be allocation-free)",
        long - short
    );
}

#[test]
fn pooled_steady_state_is_host_tensor_allocation_free() {
    // With bounded cohorts and a residency cap of 1, every round churns
    // the pool (evict → spill → rematerialize from recycled arenas) —
    // and after the watermark round the whole loop, evictions included,
    // must allocate zero HostTensors.
    let Some(e) = engine() else { return };
    assert_counters_live(&mini_cfg());
    let allocs_for = |rounds: usize| {
        let mut cfg = mini_cfg();
        cfg.train.max_rounds = rounds;
        cfg.train.max_participants = 2;
        cfg.pool.state_cap = 1;
        let mut t = Session::new(&e, &cfg).unwrap();
        let before = sfl::tensor::alloc_count();
        t.run_to_convergence().unwrap();
        sfl::tensor::alloc_count() - before
    };
    let short = allocs_for(2);
    let long = allocs_for(4);
    assert_eq!(
        long, short,
        "pooled rounds 3-4 allocated {} extra HostTensors (steady state must be allocation-free)",
        long - short
    );
}

#[test]
fn shared_data_pool_lifts_corpus_fleet_cap() {
    // 4000 mini-batch-8 clients need 32k examples for disjoint shards —
    // more than the 16k corpus.  The pre-pool session refused to start;
    // the shared data pool + state pool run it numerically with bounded
    // cohorts and O(active) state.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.apply_fleet(sfl::fleet::FleetSpec::new(sfl::fleet::FleetPreset::Paper, 4000, 5));
    cfg.train.max_rounds = 2;
    cfg.train.max_participants = 2;
    cfg.pool.state_cap = 2;
    let mut s = Session::new(&e, &cfg).unwrap();
    assert!(s.env().data.is_shared(), "4000 clients over 16k examples must share the pool");
    while !s.done() {
        let rep = s.step_round().unwrap();
        assert!(rep.participants.len() <= 2);
        assert!(rep.mean_loss.is_finite());
        let pool = rep.pool.expect("pooled run must stream pool counters");
        assert!(pool.resident <= 2);
    }
    // Full participation over the same fleet is still (correctly)
    // infeasible: the corpus cannot cover a 4000-client cohort.
    let mut infeasible = cfg.clone();
    infeasible.train.max_participants = 0;
    assert!(Session::new(&e, &infeasible).is_err());
}

#[test]
fn round_loop_does_not_clone_client_configs() {
    // The round loop is index-based (`aggregation_time_for`,
    // `sl_round_for`, `sfl_step_for`): after construction, stepping
    // rounds must clone zero participant `ClientConfig`s (each clone
    // allocates the device-name String) — the same steady-state
    // discipline as `tensor::alloc_count`, measured by
    // `config::client_clone_count`.
    let Some(e) = engine() else { return };
    assert_counters_live(&mini_cfg());
    for scheme in [SchemeKind::Ours, SchemeKind::Sfl, SchemeKind::Sl] {
        let mut cfg = mini_cfg();
        cfg.scheme = scheme;
        cfg.train.max_rounds = 4;
        cfg.train.dropout_prob = 0.3; // exercise the participant path
        let mut s = Session::new(&e, &cfg).unwrap();
        let before = sfl::config::client_clone_count();
        while !s.done() {
            s.step_round().unwrap();
        }
        assert_eq!(
            sfl::config::client_clone_count(),
            before,
            "{scheme:?}: round loop cloned ClientConfigs"
        );
    }
}

#[test]
fn all_three_schemes_complete_and_rank_correctly() {
    let Some(e) = engine() else { return };
    let mut times = std::collections::HashMap::new();
    let mut finals = Vec::new();
    for scheme in [SchemeKind::Sl, SchemeKind::Sfl, SchemeKind::Ours] {
        let mut cfg = mini_cfg();
        cfg.scheme = scheme;
        cfg.train.max_rounds = 4;
        let r = Session::new(&e, &cfg).unwrap().run_to_convergence().unwrap();
        assert_eq!(r.rounds.len(), 4);
        times.insert(format!("{scheme:?}"), r.rounds.last().unwrap().sim_time);
        finals.push((scheme, r.memory_mb));
    }
    // Per-round virtual time: SL slowest, Ours fastest (paper Fig. 2c).
    assert!(times["Sl"] > times["Sfl"], "{times:?}");
    assert!(times["Sfl"] > times["Ours"], "{times:?}");
    // Memory: SFL largest, SL smallest or close to ours (Table I).
    let mem: std::collections::HashMap<_, _> =
        finals.iter().map(|(s, m)| (format!("{s:?}"), *m)).collect();
    assert!(mem["Sfl"] > 3.0 * mem["Ours"], "{mem:?}");
    assert!(mem["Sl"] <= mem["Ours"] * 1.05, "{mem:?}");
}

#[test]
fn schedulers_share_numerics_but_differ_in_time() {
    // The scheduler must not change *what* is learned (same batches, same
    // updates) — only the virtual-clock timing. This is the invariant
    // that makes Fig. 2(a) "same curve, shifted in time".
    let Some(e) = engine() else { return };
    let run = |kind: SchedulerKind| {
        let mut cfg = mini_cfg();
        cfg.scheduler = kind;
        cfg.train.max_rounds = 3;
        Session::new(&e, &cfg).unwrap().run_to_convergence().unwrap()
    };
    let a = run(SchedulerKind::Proposed);
    let b = run(SchedulerKind::Fifo);
    // Identical training losses per round (same numeric trajectory)...
    for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
        assert!(
            (ra.mean_loss - rb.mean_loss).abs() < 1e-6,
            "numerics diverged: {} vs {}",
            ra.mean_loss,
            rb.mean_loss
        );
    }
    // ...but different (not slower-or-equal) virtual time for FIFO.
    assert!(
        a.rounds.last().unwrap().sim_time <= b.rounds.last().unwrap().sim_time + 1e-9,
        "proposed must not be slower than fifo"
    );
}

#[test]
fn random_scheduler_timing_matches_executed_orders() {
    // Regression for the stateful-scheduler divergence: the session
    // must draw ONE order per step and account virtual time against
    // exactly the orders it executes.  Replaying the scheduler's RNG
    // stream here (one draw per step) must reproduce the session's
    // clock; the old code drew a separate order for timing once per
    // round and re-sampled per step for execution, interleaving the
    // stream — which fails this reconstruction.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.scheduler = SchedulerKind::Random;
    cfg.train.max_rounds = 3;
    let r = Session::new(&e, &cfg).unwrap().run_to_convergence().unwrap();

    let dims = cfg.timing_dims();
    let cuts = cfg.resolve_cuts();
    let jobs = timing::build_jobs(&dims, &cfg.clients, &cuts, &cfg.server);
    let agg = timing::aggregation_time(&dims, &cfg.clients, &cuts);
    let mut sched = RandomScheduler::new(cfg.train.seed);
    let mut order = Vec::new();
    let mut clock = 0.0f64;
    for (round, rec) in r.rounds.iter().enumerate() {
        let mut elapsed = 0.0f64;
        for _ in 0..cfg.train.steps_per_round {
            sched.order_into(&jobs, &mut order);
            elapsed += makespan(&jobs, &order);
        }
        clock += elapsed;
        assert!(
            (rec.sim_time - clock).abs() < 1e-9,
            "round {}: session clock {} != executed-order clock {}",
            round + 1,
            rec.sim_time,
            clock
        );
        if (round + 1) % cfg.train.aggregation_interval == 0 {
            clock += agg;
        }
    }
}

#[test]
fn bounded_participation_caps_round_cohorts() {
    // --max-participants: every round trains at most the cap, traffic
    // and executions shrink accordingly, and the run still learns.
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.train.max_rounds = 4;
    cfg.train.max_participants = 2;
    let mut session = Session::new(&e, &cfg).unwrap();
    let mut max_seen = 0usize;
    while !session.done() {
        let rep = session.step_round().unwrap();
        assert!(rep.participants.len() <= 2, "round {} overflowed", rep.round);
        // Participant ids stay sorted global ids.
        assert!(rep.participants.windows(2).all(|w| w[0] < w[1]));
        max_seen = max_seen.max(rep.participants.len());
    }
    assert_eq!(max_seen, 2);
    let capped = session.result();
    let full = Session::new(&e, &mini_cfg_rounds(4)).unwrap().run_to_convergence().unwrap();
    assert!(capped.executions < full.executions);
    assert!(capped.rounds.iter().all(|x| x.mean_loss.is_finite()));
}

fn mini_cfg_rounds(rounds: usize) -> ExperimentConfig {
    let mut cfg = mini_cfg();
    cfg.train.max_rounds = rounds;
    cfg
}

#[test]
fn aggregation_interval_controls_uploads() {
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.train.max_rounds = 4;
    cfg.train.aggregation_interval = 2;
    let r2 = Session::new(&e, &cfg).unwrap().run_to_convergence().unwrap();
    cfg.train.aggregation_interval = 4;
    let r4 = Session::new(&e, &cfg).unwrap().run_to_convergence().unwrap();
    // Two aggregations vs one: double the LoRA upload traffic share.
    let lora_up = |r: &sfl::coordinator::RunResult| {
        r.uplink_bytes as f64 - r.downlink_bytes as f64 // acts==grads cancel
    };
    assert!(
        (lora_up(&r2) - 0.0).abs() < 1e-6 && (lora_up(&r4) - 0.0).abs() < 1e-6,
        "uplink/downlink symmetric in this protocol"
    );
    assert!(r2.uplink_bytes > r4.uplink_bytes, "more aggregation, more traffic");
}

#[test]
fn dropout_failure_injection_still_trains() {
    let Some(e) = engine() else { return };
    let mut cfg = mini_cfg();
    cfg.train.max_rounds = 4;
    cfg.train.dropout_prob = 0.4;
    let r = Session::new(&e, &cfg).unwrap().run_to_convergence().unwrap();
    // Fewer client-steps executed than the no-dropout run...
    let mut full = mini_cfg();
    full.train.max_rounds = 4;
    let rf = Session::new(&e, &full).unwrap().run_to_convergence().unwrap();
    assert!(r.executions < rf.executions, "{} vs {}", r.executions, rf.executions);
    // ...but the run completes, evaluates, and still learns something.
    assert_eq!(r.rounds.len(), 4);
    assert!(r.final_acc > 0.0);
    assert!(r.rounds.iter().all(|x| x.mean_loss.is_finite()));
}

#[test]
fn sl_fluctuates_more_than_ours_across_rounds() {
    // Paper §V-B: "the effect of SL fluctuates because the clients' local
    // datasets are non-IID". Quantified as the std-dev of round losses
    // being at least as large as Ours' (aggregation smooths Ours).
    let Some(e) = engine() else { return };
    let run = |scheme: SchemeKind| {
        let mut cfg = mini_cfg();
        cfg.scheme = scheme;
        cfg.train.max_rounds = 6;
        cfg.train.dirichlet_alpha = 0.1; // strongly non-IID
        let r = Session::new(&e, &cfg).unwrap().run_to_convergence().unwrap();
        let losses: Vec<f64> = r.rounds.iter().map(|x| x.mean_loss as f64).collect();
        let mean = losses.iter().sum::<f64>() / losses.len() as f64;
        losses.iter().map(|l| (l - mean).powi(2)).sum::<f64>() / losses.len() as f64
    };
    let var_sl = run(SchemeKind::Sl);
    let var_ours = run(SchemeKind::Ours);
    // SL's per-round loss bounces between client distributions; allow a
    // generous margin to keep the test robust.
    assert!(
        var_sl > var_ours * 0.5,
        "expected SL variance ({var_sl:.5}) to be comparable or larger than Ours ({var_ours:.5})"
    );
}
