//! Coordinator integration: full scheme runs over the mini artifacts.
//! One engine is shared; each sub-test uses few rounds to stay fast.

use sfl::config::{ExperimentConfig, SchedulerKind, SchemeKind};
use sfl::coordinator::Trainer;
use sfl::runtime::Engine;
use std::path::Path;

fn engine() -> Engine {
    Engine::load(Path::new("artifacts"), "mini")
        .expect("artifacts/mini missing — run `make artifacts` first")
}

fn mini_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::mini();
    c.train.max_rounds = 6;
    c.train.steps_per_round = 2;
    c.train.eval_interval = 2;
    c.train.eval_batches = 4;
    c.train.aggregation_interval = 2;
    c.train.lr = 5e-3;
    c
}

#[test]
fn ours_trains_and_reports() {
    let e = engine();
    let cfg = mini_cfg();
    let t = Trainer::new(&e, &cfg).unwrap();
    assert_eq!(t.cuts(), &[1, 1, 2, 2, 3, 3]);
    let r = t.run(true).unwrap();

    assert_eq!(r.scheme, SchemeKind::Ours);
    assert_eq!(r.rounds.len(), 6);
    // Virtual time advances monotonically.
    for w in r.rounds.windows(2) {
        assert!(w[1].sim_time > w[0].sim_time);
    }
    // Loss trends down (first vs last round mean).
    let first = r.rounds.first().unwrap().mean_loss;
    let last = r.rounds.last().unwrap().mean_loss;
    assert!(last < first, "loss did not improve: {first} -> {last}");
    // Eval series populated at the eval interval.
    assert_eq!(r.acc.points.len(), 3);
    assert!(r.final_acc > 0.0);
    // Adapter switching happened (sequential server, 6 clients).
    assert!(r.adapter_switches >= 6);
    // Memory model: Ours uses the ours accountant.
    assert!(r.memory_mb > 0.0);
}

#[test]
fn all_three_schemes_complete_and_rank_correctly() {
    let e = engine();
    let mut times = std::collections::HashMap::new();
    let mut finals = Vec::new();
    for scheme in [SchemeKind::Sl, SchemeKind::Sfl, SchemeKind::Ours] {
        let mut cfg = mini_cfg();
        cfg.scheme = scheme;
        cfg.train.max_rounds = 4;
        let r = Trainer::new(&e, &cfg).unwrap().run(true).unwrap();
        assert_eq!(r.rounds.len(), 4);
        times.insert(format!("{scheme:?}"), r.rounds.last().unwrap().sim_time);
        finals.push((scheme, r.memory_mb));
    }
    // Per-round virtual time: SL slowest, Ours fastest (paper Fig. 2c).
    assert!(times["Sl"] > times["Sfl"], "{times:?}");
    assert!(times["Sfl"] > times["Ours"], "{times:?}");
    // Memory: SFL largest, SL smallest or close to ours (Table I).
    let mem: std::collections::HashMap<_, _> =
        finals.iter().map(|(s, m)| (format!("{s:?}"), *m)).collect();
    assert!(mem["Sfl"] > 3.0 * mem["Ours"], "{mem:?}");
    assert!(mem["Sl"] <= mem["Ours"] * 1.05, "{mem:?}");
}

#[test]
fn schedulers_share_numerics_but_differ_in_time() {
    // The scheduler must not change *what* is learned (same batches, same
    // updates) — only the virtual-clock timing. This is the invariant
    // that makes Fig. 2(a) "same curve, shifted in time".
    let e = engine();
    let run = |kind: SchedulerKind| {
        let mut cfg = mini_cfg();
        cfg.scheduler = kind;
        cfg.train.max_rounds = 3;
        Trainer::new(&e, &cfg).unwrap().run(true).unwrap()
    };
    let a = run(SchedulerKind::Proposed);
    let b = run(SchedulerKind::Fifo);
    // Identical training losses per round (same numeric trajectory)...
    for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
        assert!(
            (ra.mean_loss - rb.mean_loss).abs() < 1e-6,
            "numerics diverged: {} vs {}",
            ra.mean_loss,
            rb.mean_loss
        );
    }
    // ...but different (not slower-or-equal) virtual time for FIFO.
    assert!(
        a.rounds.last().unwrap().sim_time <= b.rounds.last().unwrap().sim_time + 1e-9,
        "proposed must not be slower than fifo"
    );
}

#[test]
fn aggregation_interval_controls_uploads() {
    let e = engine();
    let mut cfg = mini_cfg();
    cfg.train.max_rounds = 4;
    cfg.train.aggregation_interval = 2;
    let r2 = Trainer::new(&e, &cfg).unwrap().run(true).unwrap();
    cfg.train.aggregation_interval = 4;
    let r4 = Trainer::new(&e, &cfg).unwrap().run(true).unwrap();
    // Two aggregations vs one: double the LoRA upload traffic share.
    let lora_up = |r: &sfl::coordinator::RunResult| {
        r.uplink_bytes as f64 - r.downlink_bytes as f64 // acts==grads cancel
    };
    assert!(
        (lora_up(&r2) - 0.0).abs() < 1e-6 && (lora_up(&r4) - 0.0).abs() < 1e-6,
        "uplink/downlink symmetric in this protocol"
    );
    assert!(r2.uplink_bytes > r4.uplink_bytes, "more aggregation, more traffic");
}

#[test]
fn dropout_failure_injection_still_trains() {
    let e = engine();
    let mut cfg = mini_cfg();
    cfg.train.max_rounds = 4;
    cfg.train.dropout_prob = 0.4;
    let r = Trainer::new(&e, &cfg).unwrap().run(true).unwrap();
    // Fewer client-steps executed than the no-dropout run...
    let mut full = mini_cfg();
    full.train.max_rounds = 4;
    let rf = Trainer::new(&e, &full).unwrap().run(true).unwrap();
    assert!(r.executions < rf.executions, "{} vs {}", r.executions, rf.executions);
    // ...but the run completes, evaluates, and still learns something.
    assert_eq!(r.rounds.len(), 4);
    assert!(r.final_acc > 0.0);
    assert!(r.rounds.iter().all(|x| x.mean_loss.is_finite()));
}

#[test]
fn sl_fluctuates_more_than_ours_across_rounds() {
    // Paper §V-B: "the effect of SL fluctuates because the clients' local
    // datasets are non-IID". Quantified as the std-dev of round losses
    // being at least as large as Ours' (aggregation smooths Ours).
    let e = engine();
    let run = |scheme: SchemeKind| {
        let mut cfg = mini_cfg();
        cfg.scheme = scheme;
        cfg.train.max_rounds = 6;
        cfg.train.dirichlet_alpha = 0.1; // strongly non-IID
        let r = Trainer::new(&e, &cfg).unwrap().run(true).unwrap();
        let losses: Vec<f64> = r.rounds.iter().map(|x| x.mean_loss as f64).collect();
        let mean = losses.iter().sum::<f64>() / losses.len() as f64;
        losses.iter().map(|l| (l - mean).powi(2)).sum::<f64>() / losses.len() as f64
    };
    let var_sl = run(SchemeKind::Sl);
    let var_ours = run(SchemeKind::Ours);
    // SL's per-round loss bounces between client distributions; allow a
    // generous margin to keep the test robust.
    assert!(
        var_sl > var_ours * 0.5,
        "expected SL variance ({var_sl:.5}) to be comparable or larger than Ours ({var_ours:.5})"
    );
}
