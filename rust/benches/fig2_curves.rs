//! Bench: regenerate **Fig. 2(a)/(b)** — accuracy and F1 vs training
//! time for the five compared schemes (SL, SFL, FIFO, WF, Ours).
//!
//! Emits the same series the paper plots as CSV under results/ and
//! prints time-to-threshold crossings (the quantity the enlarged
//! sub-graphs in the paper compare).
//!
//!     cargo bench --bench fig2_curves

use sfl::config::{ExperimentConfig, SchedulerKind, SchemeKind};
use sfl::coordinator::{RunResult, Session};
use sfl::runtime::Engine;
use sfl::telemetry;
use sfl::util::bench::bench_once;
use std::path::Path;

fn main() {
    let engine = Engine::load(Path::new("artifacts"), "mini")
        .expect("run `make artifacts` first");
    engine.warmup(&[1, 2, 3]).unwrap();

    let mut cfg = ExperimentConfig::mini();
    cfg.train.max_rounds = std::env::var("SFL_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);
    cfg.train.steps_per_round = 4;
    cfg.train.eval_interval = 3;
    cfg.train.eval_batches = 8;
    cfg.train.lr = 5e-3;

    let variants: [(&str, SchemeKind, SchedulerKind); 5] = [
        ("SL", SchemeKind::Sl, SchedulerKind::Proposed),
        ("SFL", SchemeKind::Sfl, SchedulerKind::Proposed),
        ("FIFO", SchemeKind::Ours, SchedulerKind::Fifo),
        ("WF", SchemeKind::Ours, SchedulerKind::WorkloadFirst),
        ("Ours", SchemeKind::Ours, SchedulerKind::Proposed),
    ];
    let mut results: Vec<(&str, RunResult)> = Vec::new();
    for (name, scheme, sched) in variants {
        let mut c = cfg.clone();
        c.scheme = scheme;
        c.scheduler = sched;
        let mut session = Session::new(&engine, &c).unwrap();
        let (r, _) = bench_once(&format!("fig2/{name}"), || session.run_to_convergence().unwrap());
        results.push((name, r));
    }

    let rows: Vec<(&str, &RunResult)> = results.iter().map(|(n, r)| (*n, r)).collect();
    let out = Path::new("results");
    telemetry::write_result(out, "fig2a_accuracy.csv", &telemetry::fig2_csv(&rows, "accuracy"))
        .unwrap();
    telemetry::write_result(out, "fig2b_f1.csv", &telemetry::fig2_csv(&rows, "f1")).unwrap();

    // Time-to-accuracy crossings (what the paper's zoomed panels show).
    let target = rows
        .iter()
        .map(|(_, r)| r.final_acc)
        .fold(f64::INFINITY, f64::min)
        * 0.95;
    println!("\ntime to reach accuracy {target:.3}:");
    for (name, r) in &rows {
        match r.acc.time_to_reach(target) {
            Some(t) => println!("  {name:<5} {t:10.1}s"),
            None => println!("  {name:<5}        n/a"),
        }
    }
}
