//! Memory-scale bench: pooled vs eager per-client state residency at
//! N ∈ {10, 100, 1k, 10k} synthetic clients (lognormal preset), driven
//! through the real `StatePool` + `DataPool` machinery — acquire /
//! evict / spill / aggregate — with a bounded 32-client cohort per
//! round.  Records peak resident state bytes and per-round wall-clock
//! for both modes into `BENCH_memory.json`, cross-checked against the
//! analytic `model::memory` accountant.  Pure host-side — no PJRT
//! artifacts needed (the numeric bit-identity of pooled vs eager runs
//! is asserted by the artifact-gated session tests instead).
//!
//!     cargo bench --bench mem_scale              # full sweep (10k eager ≈ 1 GB)
//!     MEM_SMOKE=1 cargo bench --bench mem_scale  # CI smoke (N ≤ 1000)
//!
//! The 10k case is the acceptance gate: pooled peak resident state must
//! be ≤ 5% of eager's, with zero `HostTensor` allocations per round
//! after warm-up.

use sfl::config::ExperimentConfig;
use sfl::data::{self, DataPool};
use sfl::fleet::{FleetPreset, FleetSpec};
use sfl::lora::{fedavg_joined_into, AdapterSet};
use sfl::model::{memory, ModelDims};
use sfl::pool::{PoolStats, StatePool};
use sfl::runtime::HeadState;
use sfl::tensor::{alloc_count, ops, rng::Rng, HostTensor};
use std::time::Instant;

const COHORT: usize = 32;
const ROUNDS: u64 = 20;
const WARMUP_ROUNDS: u64 = 8;

struct DriveResult {
    stats: PoolStats,
    median_round_ns: u128,
    steady_allocs: u64,
    resident_cuts: Vec<usize>,
}

fn mk_head(d: &ModelDims) -> HeadState {
    HeadState {
        w: HostTensor::zeros("head.w", vec![d.hidden, d.classes]),
        b: HostTensor::zeros("head.b", vec![d.classes]),
    }
}

/// Simulate `ROUNDS` rounds of bounded-cohort training against the
/// pool: acquire (materialize/unspill), touch state in place, and run
/// the fused aggregation every other round — the same pool surface the
/// session's round loop exercises, minus the PJRT engine.
fn drive(d: &ModelDims, cuts: &[usize], dpool: &DataPool, cap: usize) -> DriveResult {
    let n = cuts.len();
    let cohort = COHORT.min(n);
    let full0 = AdapterSet::init(d, d.layers, 42);
    let mut pool = StatePool::new(d, cuts, full0, mk_head(d), 100, cap, dpool)
        .expect("pool construction");
    let mut agg = AdapterSet::zeros(d, d.layers);
    let mut agg_head = mk_head(d);
    let mut ids: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(1234);
    let mut round_times: Vec<u128> = Vec::with_capacity(ROUNDS as usize);
    let mut allocs_at_steady = 0u64;
    for round in 1..=ROUNDS {
        if round == WARMUP_ROUNDS + 1 {
            allocs_at_steady = alloc_count();
        }
        let t0 = Instant::now();
        // Uniform cohort sample (partial Fisher–Yates, like the session).
        for i in 0..cohort {
            let j = i + rng.below(n - i);
            ids.swap(i, j);
        }
        pool.begin_round(round, cohort).expect("begin_round");
        for &u in ids.iter().take(cohort) {
            let slot = pool.acquire(u, dpool).expect("acquire");
            let _ = slot.it.next_batch();
            // Simulated in-place training touch.
            slot.cs.step += 1;
            slot.ss.step += 1;
            slot.cs.adam.m[0].as_f32_mut().unwrap()[0] += 1.0;
            slot.cs.lora.tensors[0].as_f32_mut().unwrap()[0] += 0.5;
        }
        if round % 2 == 0 {
            let w = 1.0 / cohort as f32;
            let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> = ids[..cohort]
                .iter()
                .map(|&u| {
                    let s = pool.resident(u).expect("cohort resident");
                    (w, &s.cs.lora, &s.ss.lora)
                })
                .collect();
            fedavg_joined_into(&contribs, &mut agg).expect("fedavg");
            let heads_w: Vec<(f32, &HostTensor)> = ids[..cohort]
                .iter()
                .map(|&u| (w, &pool.resident(u).expect("resident").ss.head.w))
                .collect();
            ops::weighted_sum_into(&heads_w, &mut agg_head.w).expect("head agg");
            let heads_b: Vec<(f32, &HostTensor)> = ids[..cohort]
                .iter()
                .map(|&u| (w, &pool.resident(u).expect("resident").ss.head.b))
                .collect();
            ops::weighted_sum_into(&heads_b, &mut agg_head.b).expect("head agg");
            pool.apply_aggregate(&agg, &agg_head).expect("apply_aggregate");
        }
        round_times.push(t0.elapsed().as_nanos());
    }
    let steady_allocs = alloc_count() - allocs_at_steady;
    let mut sorted = round_times.clone();
    sorted.sort_unstable();
    DriveResult {
        stats: pool.stats(),
        median_round_ns: sorted[sorted.len() / 2],
        steady_allocs,
        resident_cuts: pool.resident_cuts(),
    }
}

fn main() {
    let smoke = std::env::var("MEM_SMOKE").map(|v| v == "1").unwrap_or(false);
    let max_n: usize = if smoke { 1_000 } else { 10_000 };
    let dims = ModelDims::mini();
    let spec = data::CorpusSpec { seed: 7, ..data::CorpusSpec::carer_like(dims.vocab, dims.seq) };
    let ds = data::generate(&spec);
    let base_cfg = ExperimentConfig::paper();
    let mut entries: Vec<(String, String)> = Vec::new();

    for n in [10usize, 100, 1_000, 10_000] {
        if n > max_n {
            println!("mem_scale: skipping n={n} (MEM_SMOKE caps the sweep at {max_n})");
            continue;
        }
        let mut spec_f = FleetSpec::new(FleetPreset::Lognormal, n, 11);
        spec_f.mfu_sigma = 0.2;
        let mut cfg = base_cfg.clone();
        cfg.apply_fleet(spec_f);
        let cuts = cfg.resolve_cuts();
        let dpool = DataPool::new(&ds.train, n, 0.5, 8, dims.batch);
        println!(
            "mem_scale n={n}: data pool mode = {}",
            if dpool.is_shared() { "shared (derived shards)" } else { "dense (exact Dirichlet)" }
        );

        let cap = COHORT.min(n);
        let pooled = drive(&dims, &cuts, &dpool, cap);
        let eager = drive(&dims, &cuts, &dpool, 0);
        let eager_bytes = eager.stats.peak_resident_bytes;
        let pooled_bytes = pooled.stats.peak_resident_bytes;
        println!(
            "mem resident n={n:<6} pooled={pooled_bytes:>12} B  eager={eager_bytes:>12} B  \
             ratio={:.4}  (hits={} misses={} evictions={} spill={} B)",
            pooled_bytes as f64 / eager_bytes as f64,
            pooled.stats.hits,
            pooled.stats.misses,
            pooled.stats.evictions,
            pooled.stats.spill_bytes,
        );
        println!(
            "mem round   n={n:<6} pooled={:>10} ns  eager={:>10} ns",
            pooled.median_round_ns, eager.median_round_ns
        );
        assert_eq!(
            pooled.steady_allocs, 0,
            "pooled steady state allocated HostTensors at n={n}"
        );
        assert_eq!(
            eager.steady_allocs, 0,
            "eager steady state allocated HostTensors at n={n}"
        );

        // Cross-check the measured residency ratio against the analytic
        // accountant (model/memory.rs): both must agree that pooled
        // client state is O(cohort), not O(fleet).
        let analytic_eager = memory::ours_server_memory(&dims, &cuts).lora_states;
        let analytic_pooled =
            memory::pooled_server_memory(&dims, &cuts, &pooled.resident_cuts).lora_states;
        let analytic_ratio = analytic_pooled / analytic_eager;
        let measured_ratio = pooled_bytes as f64 / eager_bytes as f64;
        // Generous band: the measured per-client bytes are
        // cut-independent while the analytic accountant varies with the
        // resident cut mix, so the two ratios agree to a small factor,
        // not exactly.
        assert!(
            measured_ratio <= analytic_ratio * 3.0 && measured_ratio >= analytic_ratio * 0.2,
            "measured residency ratio {measured_ratio:.4} disagrees with analytic \
             {analytic_ratio:.4} at n={n}"
        );
        if n == 10_000 {
            // Acceptance gate: ≤ 5% of eager on the 10k fleet.
            assert!(
                pooled_bytes * 20 <= eager_bytes,
                "pooled peak {pooled_bytes} B exceeds 5% of eager {eager_bytes} B at n=10k"
            );
            println!("accept: pooled peak ≤ 5% of eager at n=10k, zero steady-state allocs");
        }

        for (mode, r) in [("pooled", &pooled), ("eager", &eager)] {
            entries.push((
                format!("mem/peak_resident_bytes/{mode}/n{n}"),
                r.stats.peak_resident_bytes.to_string(),
            ));
            entries.push((format!("mem/round_ns/{mode}/n{n}"), r.median_round_ns.to_string()));
        }
        entries.push((format!("mem/hits/pooled/n{n}"), pooled.stats.hits.to_string()));
        entries.push((format!("mem/misses/pooled/n{n}"), pooled.stats.misses.to_string()));
        entries.push((
            format!("mem/evictions/pooled/n{n}"),
            pooled.stats.evictions.to_string(),
        ));
        entries.push((
            format!("mem/spill_bytes/pooled/n{n}"),
            pooled.stats.spill_bytes.to_string(),
        ));
        entries.push((
            format!("mem/analytic_ratio/n{n}"),
            format!("{:.6}", analytic_ratio),
        ));
    }

    let mut json = String::from("{\n");
    for (i, (name, value)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        json.push_str(&format!("  \"{name}\": {value}{comma}\n"));
    }
    json.push_str("}\n");
    match std::fs::write("BENCH_memory.json", &json) {
        Ok(()) => println!("wrote BENCH_memory.json ({} entries)", entries.len()),
        Err(e) => eprintln!("could not write BENCH_memory.json: {e}"),
    }
}
