//! L3 hot-path micro-benches (the §Perf profile targets): adapter
//! split/join/FedAvg (in-place vs allocating), literal marshaling,
//! per-call PJRT latency for every artifact, and the event-queue/
//! scheduler substrate.
//!
//!     cargo bench --bench hotpath
//!
//! The tracked names (`lora/split_at`, `lora/join`,
//! `lora/fedavg-6-clients`) bench the *current hot-path
//! implementation* — view-based/in-place since the zero-allocation
//! refactor — and the `*_alloc` companions keep the old allocating
//! path measured for comparison.  Results are printed as grep-able
//! lines and written to BENCH_hotpath.json (name → median ns) so the
//! perf trajectory is tracked across PRs.
//!
//! The host-side section needs no artifacts; the PJRT section is
//! skipped (with a note) when artifacts/mini is missing.

use sfl::lora::{fedavg, fedavg_into, fedavg_joined_into, AdapterSet};
use sfl::model::ModelDims;
use sfl::runtime::{ClientState, Engine, ServerState};
use sfl::simclock::EventQueue;
use sfl::tensor::{alloc_count, HostTensor};
use sfl::util::bench::{bench, BenchResult};
use std::path::Path;

/// Engine for the PJRT section, or None (with a note) when the
/// artifacts are missing or the vendored xla stub is linked.
fn pjrt_engine() -> Option<Engine> {
    if !Path::new("artifacts/mini/manifest.txt").exists() {
        eprintln!("hotpath: artifacts/mini missing — skipping PJRT benches (run `make artifacts`)");
        return None;
    }
    let engine = Engine::load(Path::new("artifacts"), "mini").expect("loading artifacts/mini");
    if let Err(err) = engine.warmup(&[1, 2, 3]) {
        let msg = err.to_string();
        if msg.contains("offline xla stub") {
            eprintln!(
                "hotpath: vendored xla stub active — skipping PJRT benches \
                 (swap in the real `xla` crate, see rust/Cargo.toml)"
            );
            return None;
        }
        panic!("warmup(artifacts/mini) failed: {msg}");
    }
    Some(engine)
}

fn write_json(results: &[BenchResult]) {
    let mut json = String::from("{\n");
    for (i, r) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        json.push_str(&format!(
            "  \"{}\": {}{comma}\n",
            r.name,
            r.median.as_nanos()
        ));
    }
    json.push_str("}\n");
    let path = "BENCH_hotpath.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path} ({} entries)", results.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let dims = ModelDims::mini();

    // --- host-side adapter ops (aggregation path; no artifacts) ---
    let full = AdapterSet::init(&dims, dims.layers, 1);

    // Hot-path split: O(1) views (tracked name).
    results.push(bench("lora/split_at", 10, 500, || {
        let _ = full.split_at_views(2).unwrap();
    }));
    // Old allocating split, kept for comparison.
    results.push(bench("lora/split_at_alloc", 10, 500, || {
        let _ = full.split_at(2).unwrap();
    }));

    let (c2, s2) = full.split_at(2).unwrap();
    // Hot-path join: writes into a preallocated full set (tracked name).
    let mut joined = AdapterSet::zeros(&dims, dims.layers);
    results.push(bench("lora/join", 10, 500, || {
        AdapterSet::join_into(&c2, &s2, &mut joined).unwrap();
    }));
    results.push(bench("lora/join_alloc", 10, 500, || {
        let _ = AdapterSet::join(&c2, &s2).unwrap();
    }));

    let sets: Vec<AdapterSet> =
        (0..6).map(|i| AdapterSet::init(&dims, dims.layers, i)).collect();
    let w = 1.0 / 6.0f32;
    // Hot-path FedAvg: fused single pass into scratch (tracked name).
    let pairs: Vec<(f32, &AdapterSet)> = sets.iter().map(|s| (w, s)).collect();
    let mut agg = AdapterSet::zeros(&dims, dims.layers);
    results.push(bench("lora/fedavg-6-clients", 10, 200, || {
        fedavg_into(&pairs, &mut agg).unwrap();
    }));
    results.push(bench("lora/fedavg-6-clients-alloc", 10, 200, || {
        let pairs: Vec<(f32, &AdapterSet)> = sets.iter().map(|s| (w, s)).collect();
        let _ = fedavg(&pairs).unwrap();
    }));

    // Fused heterogeneous aggregation (what the session's parallel
    // schemes run): mixed cuts, halves scattered straight into the
    // aggregate.
    let halves: Vec<(AdapterSet, AdapterSet)> = sets
        .iter()
        .enumerate()
        .map(|(i, s)| s.split_at(1 + i % 3).unwrap())
        .collect();
    let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> =
        halves.iter().map(|(c, s)| (w, c, s)).collect();
    results.push(bench("lora/fedavg-joined-6-clients", 10, 200, || {
        fedavg_joined_into(&contribs, &mut agg).unwrap();
    }));

    // The in-place suite must not allocate a single HostTensor.
    {
        let before = alloc_count();
        let _ = full.split_at_views(2).unwrap();
        AdapterSet::join_into(&c2, &s2, &mut joined).unwrap();
        fedavg_into(&pairs, &mut agg).unwrap();
        fedavg_joined_into(&contribs, &mut agg).unwrap();
        let after = alloc_count();
        assert_eq!(after, before, "in-place hot path allocated {} HostTensors", after - before);
        println!("alloc-check: in-place split/join/fedavg suite → 0 HostTensor allocations");
    }

    // --- marshaling substrate: payload byte views ---
    let big = HostTensor::zeros("m", vec![64, 64, 16]);
    results.push(bench("tensor/payload_bytes", 10, 1000, || {
        let _ = std::hint::black_box(big.payload_bytes());
    }));
    results.push(bench("tensor/to_le_bytes_alloc", 5, 100, || {
        let _ = std::hint::black_box(big.to_le_bytes());
    }));

    // --- PJRT per-call latency, every artifact kind (needs artifacts
    //     AND the real `xla` crate — the vendored stub cannot compile) ---
    if let Some(engine) = pjrt_engine() {
        let dims = engine.dims().clone();
        let full = engine.initial_lora().unwrap();

        let mut rng = sfl::tensor::rng::Rng::new(5);
        let tokens: Vec<i32> =
            (0..dims.batch * dims.seq).map(|_| rng.below(dims.vocab) as i32).collect();
        let labels: Vec<i32> =
            (0..dims.batch).map(|_| rng.below(dims.classes) as i32).collect();
        let head = engine.initial_head().unwrap();

        let mut acts_buf =
            HostTensor::zeros("acts", vec![dims.batch, dims.seq, dims.hidden]);
        let mut grads_buf =
            HostTensor::zeros("act_grads", vec![dims.batch, dims.seq, dims.hidden]);
        for k in [1usize, 2, 3] {
            let (clora, slora) = full.split_at(k).unwrap();
            let cstate = ClientState::fresh(clora);
            let sstate = ServerState::fresh(slora, head.clone());
            results.push(bench(&format!("pjrt/client_fwd_{k}"), 3, 20, || {
                engine
                    .client_fwd_into(k, &tokens, &cstate.lora, &mut acts_buf)
                    .unwrap();
            }));
            let acts = engine.client_fwd(k, &tokens, &cstate.lora).unwrap();
            let mut s_inplace = sstate.clone();
            results.push(bench(&format!("pjrt/server_step_{k}"), 3, 20, || {
                let _ = engine
                    .server_step_into(k, &acts, &labels, &mut s_inplace, &mut grads_buf, 1e-3)
                    .unwrap();
            }));
            results.push(bench(&format!("pjrt/server_step_{k}_alloc"), 3, 20, || {
                let _ = engine.server_step(k, &acts, &labels, &sstate, 1e-3).unwrap();
            }));
            let out = engine.server_step(k, &acts, &labels, &sstate, 1e-3).unwrap();
            let mut c_inplace = cstate.clone();
            results.push(bench(&format!("pjrt/client_bwd_{k}"), 3, 20, || {
                engine
                    .client_bwd_into(k, &tokens, &mut c_inplace, &out.act_grads, 1e-3)
                    .unwrap();
            }));
        }
        results.push(bench("pjrt/eval", 3, 20, || {
            let _ = engine.eval(&tokens, &labels, &full, &head).unwrap();
        }));
        let fstate = ServerState::fresh(full.clone(), head.clone());
        results.push(bench("pjrt/full_step", 3, 20, || {
            let _ = engine.full_step(&tokens, &labels, &fstate, 1e-3).unwrap();
        }));
        println!(
            "telemetry: execs={} staged-bytes={}",
            engine.exec_count(),
            engine.bytes_uploaded()
        );
    }

    // --- coordinator substrate ---
    {
        use sfl::config::ExperimentConfig;
        use sfl::coordinator::scheduler::ProposedScheduler;
        use sfl::coordinator::timing;
        let cfg = ExperimentConfig::paper();
        let tdims = cfg.timing_dims();
        let cuts = cfg.resolve_cuts();
        results.push(bench("timing/ours_step-6-clients", 10, 1000, || {
            let _ =
                timing::ours_step(&tdims, &cfg.clients, &cuts, &cfg.server, &mut ProposedScheduler);
        }));
        results.push(bench("simclock/10k-events", 2, 50, || {
            let mut q = EventQueue::new();
            for i in 0..10_000u32 {
                q.schedule_in((i % 97) as f64 * 0.01, i);
            }
            while q.next().is_some() {}
        }));
    }

    write_json(&results);
}
