//! L3 hot-path micro-benches (the §Perf profile targets): literal
//! marshaling, adapter split/join/FedAvg, per-call PJRT latency for
//! every artifact, and the event-queue/scheduler substrate.
//!
//!     cargo bench --bench hotpath

use sfl::config::ExperimentConfig;
use sfl::coordinator::scheduler::ProposedScheduler;
use sfl::coordinator::timing;
use sfl::lora::{fedavg, AdapterSet};
use sfl::runtime::{ClientState, Engine, ServerState};
use sfl::simclock::EventQueue;
use sfl::tensor::rng::Rng;
use sfl::util::bench::bench;
use std::path::Path;

fn main() {
    let engine = Engine::load(Path::new("artifacts"), "mini")
        .expect("run `make artifacts` first");
    engine.warmup(&[1, 2, 3]).unwrap();
    let dims = engine.dims().clone();

    // --- host-side adapter ops (aggregation path) ---
    let full = engine.initial_lora().unwrap();
    bench("lora/split_at", 10, 500, || {
        let _ = full.split_at(2).unwrap();
    });
    let (c2, s2) = full.split_at(2).unwrap();
    bench("lora/join", 10, 500, || {
        let _ = AdapterSet::join(&c2, &s2).unwrap();
    });
    let sets: Vec<AdapterSet> =
        (0..6).map(|i| AdapterSet::init(&dims, dims.layers, i)).collect();
    let w = 1.0 / 6.0f32;
    bench("lora/fedavg-6-clients", 10, 200, || {
        let pairs: Vec<(f32, &AdapterSet)> = sets.iter().map(|s| (w, s)).collect();
        let _ = fedavg(&pairs).unwrap();
    });

    // --- PJRT per-call latency, every artifact kind ---
    let mut rng = Rng::new(5);
    let tokens: Vec<i32> =
        (0..dims.batch * dims.seq).map(|_| rng.below(dims.vocab) as i32).collect();
    let labels: Vec<i32> = (0..dims.batch).map(|_| rng.below(dims.classes) as i32).collect();
    let head = engine.initial_head().unwrap();

    for k in [1usize, 2, 3] {
        let (clora, slora) = full.split_at(k).unwrap();
        let cstate = ClientState::fresh(clora);
        let sstate = ServerState::fresh(slora, head.clone());
        bench(&format!("pjrt/client_fwd_{k}"), 3, 20, || {
            let _ = engine.client_fwd(k, &tokens, &cstate.lora).unwrap();
        });
        let acts = engine.client_fwd(k, &tokens, &cstate.lora).unwrap();
        bench(&format!("pjrt/server_step_{k}"), 3, 20, || {
            let _ = engine.server_step(k, &acts, &labels, &sstate, 1e-3).unwrap();
        });
        let out = engine.server_step(k, &acts, &labels, &sstate, 1e-3).unwrap();
        bench(&format!("pjrt/client_bwd_{k}"), 3, 20, || {
            let _ = engine.client_bwd(k, &tokens, &cstate, &out.act_grads, 1e-3).unwrap();
        });
    }
    bench("pjrt/eval", 3, 20, || {
        let _ = engine.eval(&tokens, &labels, &full, &head).unwrap();
    });
    let fstate = ServerState::fresh(full.clone(), head.clone());
    bench("pjrt/full_step", 3, 20, || {
        let _ = engine.full_step(&tokens, &labels, &fstate, 1e-3).unwrap();
    });

    // --- coordinator substrate ---
    let cfg = ExperimentConfig::paper();
    let tdims = cfg.timing_dims();
    let cuts = cfg.resolve_cuts();
    bench("timing/ours_step-6-clients", 10, 1000, || {
        let _ = timing::ours_step(&tdims, &cfg.clients, &cuts, &cfg.server, &mut ProposedScheduler);
    });
    bench("simclock/10k-events", 2, 50, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u32 {
            q.schedule_in((i % 97) as f64 * 0.01, i);
        }
        while q.next().is_some() {}
    });

    println!(
        "\ntelemetry: execs={} staged-bytes={}",
        engine.exec_count.get(),
        engine.bytes_uploaded.get()
    );
}
