//! Network-fault bench: loss-rate × retry-budget sweep on the
//! closed-form `channel::testbed` world (real codec on the wire, real
//! Gilbert–Elliott dice), recording quality / retry / give-up counters
//! into `BENCH_netfault.json`.  Pure host-side — no PJRT artifacts.
//!
//!     cargo bench --bench netfault                 # full sweep
//!     NETFAULT_SMOKE=1 cargo bench --bench netfault  # CI smoke (gate configs only)
//!
//! The acceptance gate (asserted in smoke runs too): at 10% loss + 2%
//! corruption the bounded-retransmission protocol with partial merges
//! recovers ≥ 97% of the clean run's quality with no honest client
//! quarantined, while the no-retry baseline measurably degrades.

use sfl::channel::testbed::{run, Scenario};

const GATE_LOSS: f64 = 0.10;
const GATE_CORRUPT: f64 = 0.02;
const GATE_RETRY: usize = 3;
const GATE_THRESHOLD: usize = 4;

fn main() {
    let smoke = std::env::var("NETFAULT_SMOKE").map(|v| v == "1").unwrap_or(false);
    let losses: &[f64] = if smoke { &[GATE_LOSS] } else { &[0.0, 0.05, GATE_LOSS, 0.20] };
    let retries: &[usize] = &[0, GATE_RETRY];
    let base = Scenario { corrupt: GATE_CORRUPT, tamper_threshold: GATE_THRESHOLD, ..Scenario::default() };
    let mut entries: Vec<(String, String)> = Vec::new();

    // Clean reference: reliable channel, same world and seed.
    let clean = run(&Scenario { corrupt: 0.0, ..base.clone() }).expect("clean run");
    println!("netfault clean: quality={:.6} (d0={:.3})", clean.quality, clean.d0);
    entries.push(("netfault/quality/clean".into(), format!("{:.6}", clean.quality)));

    let mut gate_quality = None;
    let mut noretry_quality = None;
    for &loss in losses {
        for &retry_max in retries {
            let sc = Scenario { loss, retry_max, ..base.clone() };
            let out = run(&sc).expect("scenario run");
            let tag = format!("loss{}/retry{retry_max}", (loss * 100.0).round() as u64);
            println!(
                "netfault {tag}: quality={:.6} sent={} dropped={} corrupted={} \
                 retries={} gave_up={} partial_merges={} honest_quarantined={}",
                out.quality,
                out.net.sent,
                out.net.dropped,
                out.net.corrupted,
                out.net.retries,
                out.net.gave_up,
                out.net.partial_merges,
                out.quarantined_honest
            );
            entries.push((format!("netfault/quality/{tag}"), format!("{:.6}", out.quality)));
            entries.push((format!("netfault/retries/{tag}"), out.net.retries.to_string()));
            entries.push((format!("netfault/gave_up/{tag}"), out.net.gave_up.to_string()));
            entries.push((
                format!("netfault/partial_merges/{tag}"),
                out.net.partial_merges.to_string(),
            ));
            entries.push((
                format!("netfault/honest_quarantined/{tag}"),
                out.quarantined_honest.to_string(),
            ));
            // No honest client may ever be quarantined by benign
            // channel noise, at any point of the sweep.
            assert_eq!(
                out.quarantined_honest, 0,
                "{tag}: benign corruption must never escalate an honest client"
            );
            if loss == GATE_LOSS && retry_max == GATE_RETRY {
                gate_quality = Some(out.quality);
            }
            if loss == GATE_LOSS && retry_max == 0 {
                noretry_quality = Some(out.quality);
                assert!(
                    out.net.gave_up > 0,
                    "{tag}: the no-retry baseline must be losing uploads outright"
                );
            }
        }
    }
    // Acceptance gate: retry + partial-merge degradation recovers the
    // clean quality; the no-retry baseline does not.
    let gate = gate_quality.expect("sweep must include the loss10/retry3 gate configuration");
    let noretry = noretry_quality.expect("sweep must include the loss10/retry0 baseline");
    assert!(
        gate >= 0.97 * clean.quality,
        "gate: quality {gate:.6} fell below 97% of clean {:.6}",
        clean.quality
    );
    assert!(
        noretry < gate,
        "no-retry baseline ({noretry:.6}) must degrade vs the retry protocol ({gate:.6})"
    );
    println!(
        "accept: loss10/retry3 recovers {:.2}% of clean quality (no-retry: {:.2}%)",
        100.0 * gate / clean.quality,
        100.0 * noretry / clean.quality
    );

    let mut json = String::from("{\n");
    for (i, (name, value)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        json.push_str(&format!("  \"{name}\": {value}{comma}\n"));
    }
    json.push_str("}\n");
    match std::fs::write("BENCH_netfault.json", &json) {
        Ok(()) => println!("wrote BENCH_netfault.json ({} entries)", entries.len()),
        Err(e) => eprintln!("could not write BENCH_netfault.json: {e}"),
    }
}
