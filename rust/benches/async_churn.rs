//! Async-vs-sync pacing bench on the closed-form `events::testbed`
//! world: heterogeneous clients under markov availability churn and
//! diurnal slowdowns, swept across staleness bounds and buffer sizes,
//! recording time-to-target for each mode into `BENCH_async.json`.
//! Pure host-side — the async mode runs on the real `EventEngine` with
//! the real staleness/version primitives, so no PJRT artifacts are
//! needed.
//!
//!     cargo bench --bench async_churn               # full sweep
//!     ASYNC_SMOKE=1 cargo bench --bench async_churn  # CI smoke
//!
//! The acceptance gate (asserted in smoke runs too): buffered-async
//! reaches the target strictly faster than the synchronous barrier
//! under markov churn at the default merge settings, without giving up
//! final quality.

use sfl::events::testbed::{run_async, run_sync, Scenario};
use sfl::trace::{TraceKind, TraceSpec};

fn scenario(kind: TraceKind) -> Scenario {
    Scenario { trace: TraceSpec { kind, ..TraceSpec::default() }, ..Scenario::default() }
}

fn main() {
    let smoke = std::env::var("ASYNC_SMOKE").map(|v| v == "1").unwrap_or(false);
    let bounds: &[f64] = if smoke { &[240.0] } else { &[60.0, 240.0, 960.0] };
    let ks: &[usize] = if smoke { &[4] } else { &[2, 4, 8] };
    let traces: &[(&str, TraceKind)] = if smoke {
        &[("markov", TraceKind::Markov)]
    } else {
        &[("markov", TraceKind::Markov), ("diurnal", TraceKind::Diurnal)]
    };
    let mut entries: Vec<(String, String)> = Vec::new();

    for &(name, kind) in traces {
        let base = scenario(kind);
        let sync = run_sync(&base).expect("sync run");
        println!(
            "async_churn {name}/sync: time={:.1}s rounds={} final_rel={:.4}",
            sync.time_to_target, sync.merges, sync.final_rel
        );
        entries.push((format!("async/{name}/sync/time"), format!("{:.3}", sync.time_to_target)));
        entries.push((format!("async/{name}/sync/merges"), sync.merges.to_string()));

        for &tau in bounds {
            for &k in ks {
                let sc = Scenario { staleness_bound: tau, buffer_k: k, ..base.clone() };
                let a = run_async(&sc).expect("async run");
                let tag = format!("{name}/tau{}/k{k}", tau as u64);
                println!(
                    "async_churn {tag}: time={:.1}s merges={} max_staleness={} \
                     speedup={:.2}x final_rel={:.4}",
                    a.time_to_target,
                    a.merges,
                    a.max_staleness,
                    sync.time_to_target / a.time_to_target,
                    a.final_rel
                );
                entries.push((format!("async/{tag}/time"), format!("{:.3}", a.time_to_target)));
                entries.push((
                    format!("async/{tag}/speedup"),
                    format!("{:.4}", sync.time_to_target / a.time_to_target),
                ));
                entries.push((
                    format!("async/{tag}/max_staleness"),
                    a.max_staleness.to_string(),
                ));
                assert!(
                    a.final_rel <= sc.target,
                    "{tag}: async stopped at rel {:.4} > target {:.4}",
                    a.final_rel,
                    sc.target
                );
                // Acceptance gate: default merge settings beat the
                // barrier under markov churn.
                if name == "markov" && (tau - base.staleness_bound).abs() < 1e-9 && k == base.buffer_k
                {
                    assert!(
                        a.time_to_target < sync.time_to_target,
                        "{tag}: async {:.1}s must beat sync {:.1}s under markov churn",
                        a.time_to_target,
                        sync.time_to_target
                    );
                }
            }
        }
    }
    println!("accept: buffered-async beats the barrier under markov churn at default K/τ");

    let mut json = String::from("{\n");
    for (i, (name, value)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        json.push_str(&format!("  \"{name}\": {value}{comma}\n"));
    }
    json.push_str("}\n");
    match std::fs::write("BENCH_async.json", &json) {
        Ok(()) => println!("wrote BENCH_async.json ({} entries)", entries.len()),
        Err(e) => eprintln!("could not write BENCH_async.json: {e}"),
    }
}
