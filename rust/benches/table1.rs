//! Bench: regenerate **Table I** — memory / convergence round /
//! convergence time / accuracy / F1 for SL, SFL, Ours.
//!
//! Runs the three schemes on the mini artifacts to convergence (bounded
//! rounds to keep `cargo bench` tractable on one core) and prints the
//! same rows the paper reports, plus the headline ratios.
//!
//!     cargo bench --bench table1

use sfl::config::{ExperimentConfig, SchemeKind};
use sfl::coordinator::Session;
use sfl::runtime::Engine;
use sfl::telemetry;
use sfl::util::bench::bench_once;
use std::path::Path;

fn main() {
    let engine = Engine::load(Path::new("artifacts"), "mini")
        .expect("run `make artifacts` first");
    engine.warmup(&[1, 2, 3]).unwrap();

    let mut cfg = ExperimentConfig::mini();
    cfg.train.max_rounds = std::env::var("SFL_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);
    cfg.train.steps_per_round = 4;
    cfg.train.eval_interval = 3;
    cfg.train.eval_batches = 8;
    cfg.train.lr = 5e-3;
    cfg.train.patience = 6;

    let mut results = Vec::new();
    for scheme in [SchemeKind::Sl, SchemeKind::Sfl, SchemeKind::Ours] {
        let mut c = cfg.clone();
        c.scheme = scheme;
        let mut session = Session::new(&engine, &c).unwrap();
        let (r, _) =
            bench_once(&format!("table1/{scheme}"), || session.run_to_convergence().unwrap());
        results.push((scheme.to_string(), r));
    }

    let rows: Vec<(&str, &sfl::coordinator::RunResult)> =
        results.iter().map(|(n, r)| (n.as_str(), r)).collect();
    println!("\nTable I (reproduced, mini artifacts / BERT-base timing dims):");
    println!("{}", telemetry::table1(&rows));

    let by: std::collections::HashMap<&str, &sfl::coordinator::RunResult> =
        rows.iter().copied().collect();
    println!(
        "headline: mem -{:.0}% vs SFL (paper -79%) | time -{:.0}% vs SL (paper -41%) | time -{:.1}% vs SFL (paper -6%)",
        (1.0 - by["ours"].memory_mb / by["sfl"].memory_mb) * 100.0,
        (1.0 - by["ours"].total_time() / by["sl"].total_time()) * 100.0,
        (1.0 - by["ours"].total_time() / by["sfl"].total_time()) * 100.0,
    );
}
