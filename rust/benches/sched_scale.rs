//! Fleet-scale scheduler bench: per-step makespan and scheduling
//! wall-clock for every policy at N ∈ {10, 100, 1k, 10k, 100k}
//! synthetic clients (lognormal preset, hidden MFU jitter), plus the
//! estimator-vs-oracle makespan of the proposed policy.  Results land
//! in `BENCH_sched.json` (see EXPERIMENTS.md §Scheduling for the
//! schema).  Pure timing model — no artifacts needed.
//!
//!     cargo bench --bench sched_scale            # full sweep
//!     SCHED_SCALE_MAX_N=1000 cargo bench --bench sched_scale   # CI smoke
//!
//! The 10k case doubles as the steady-state allocation gate: after
//! warm-up, order_into + makespan must perform zero `HostTensor`
//! allocations and never regrow the reused order buffer.

use sfl::config::{ExperimentConfig, SchedulerKind};
use sfl::coordinator::estimator::TimingEstimator;
use sfl::coordinator::scheduler::{make_scheduler, makespan};
use sfl::coordinator::timing::{self, StepTiming};
use sfl::fleet::{FleetPreset, FleetSpec};
use sfl::tensor::alloc_count;
use sfl::util::bench::bench;

const KINDS: [SchedulerKind; 4] = [
    SchedulerKind::Proposed,
    SchedulerKind::Fifo,
    SchedulerKind::WorkloadFirst,
    SchedulerKind::Random,
];

fn main() {
    let max_n: usize = std::env::var("SCHED_SCALE_MAX_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100_000);
    let cfg = ExperimentConfig::paper();
    let dims = cfg.timing_dims();
    let mut entries: Vec<(String, String)> = Vec::new();

    for n in [10usize, 100, 1_000, 10_000, 100_000] {
        if n > max_n {
            println!("sched_scale: skipping n={n} (SCHED_SCALE_MAX_N={max_n})");
            continue;
        }
        let mut spec = FleetSpec::new(FleetPreset::Lognormal, n, 11);
        spec.mfu_sigma = 0.2;
        let mut fleet_cfg = cfg.clone();
        fleet_cfg.apply_fleet(spec);
        let cuts = fleet_cfg.resolve_cuts();
        let clients = &fleet_cfg.clients;
        let jobs = timing::build_jobs(&dims, clients, &cuts, &fleet_cfg.server);
        let nominal_jobs = timing::build_nominal_jobs(&dims, clients, &cuts, &fleet_cfg.server);

        let mut order = Vec::with_capacity(n);
        for kind in KINDS {
            let mut s = make_scheduler(kind, 7);
            s.order_into(&jobs, &mut order); // size the buffer
            let (cap, ptr) = (order.capacity(), order.as_ptr());
            let allocs_before = alloc_count();
            let name = format!("sched/order/{}/n{n}", s.name());
            let r = bench(&name, 3, 30, || {
                s.order_into(&jobs, &mut order);
                std::hint::black_box(makespan(&jobs, &order));
            });
            if n == 10_000 {
                assert_eq!(
                    alloc_count(),
                    allocs_before,
                    "schedule path allocated HostTensors at n=10k"
                );
                assert_eq!(
                    (order.capacity(), order.as_ptr()),
                    (cap, ptr),
                    "order buffer regrew at n=10k"
                );
            }
            entries.push((name, r.median.as_nanos().to_string()));
            s.order_into(&jobs, &mut order);
            let m = makespan(&jobs, &order);
            println!("sched makespan {:<16} n={n:<7} {m:.3}s", s.name());
            entries.push((format!("sched/makespan/{}/n{n}", s.name()), format!("{m:.6}")));
        }
        if n == 10_000 {
            println!("alloc-check: schedule path at n=10k → 0 HostTensor allocations");
        }

        // Proposed policy driven by the online estimator: cold (static
        // nominal model) and warm (one full observation round).
        let mut est = TimingEstimator::new(n, 0.25);
        let mut sched = make_scheduler(SchedulerKind::Proposed, 7);
        let mut sched_jobs = Vec::with_capacity(n);
        est.jobs_into(&nominal_jobs, &mut sched_jobs);
        sched.order_into(&sched_jobs, &mut order);
        let cold = makespan(&jobs, &order);
        for j in &jobs {
            est.observe(j.client, &StepTiming::from_job(j));
        }
        est.jobs_into(&nominal_jobs, &mut sched_jobs);
        sched.order_into(&sched_jobs, &mut order);
        let warm = makespan(&jobs, &order);
        sched.order_into(&jobs, &mut order);
        let oracle = makespan(&jobs, &order);
        println!(
            "sched estimator n={n:<7} cold={cold:.3}s warm={warm:.3}s oracle={oracle:.3}s \
             (warm/oracle = {:.4})",
            warm / oracle
        );
        entries.push((format!("sched/makespan/est-cold/n{n}"), format!("{cold:.6}")));
        entries.push((format!("sched/makespan/est-warm/n{n}"), format!("{warm:.6}")));
    }

    let mut json = String::from("{\n");
    for (i, (name, value)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        json.push_str(&format!("  \"{name}\": {value}{comma}\n"));
    }
    json.push_str("}\n");
    match std::fs::write("BENCH_sched.json", &json) {
        Ok(()) => println!("wrote BENCH_sched.json ({} entries)", entries.len()),
        Err(e) => eprintln!("could not write BENCH_sched.json: {e}"),
    }
}
