//! Ablation bench: scheduler behaviour across fleet size, heterogeneity
//! spread, link rate, and aggregation interval (the design choices
//! DESIGN.md calls out) — analytic timing model, no artifacts needed.
//!
//!     cargo bench --bench ablate_scheduler

use sfl::config::{ClientConfig, ExperimentConfig, SchedulerKind};
use sfl::coordinator::scheduler::make_scheduler;
use sfl::coordinator::timing;
use sfl::devices::{paper_fleet, DeviceProfile};
use sfl::net::Link;
use sfl::tensor::rng::Rng;
use sfl::util::bench::bench;

const KINDS: [SchedulerKind; 4] = [
    SchedulerKind::Proposed,
    SchedulerKind::Fifo,
    SchedulerKind::WorkloadFirst,
    SchedulerKind::Random,
];

fn makespans(
    clients: &[ClientConfig],
    cuts: &[usize],
    cfg: &ExperimentConfig,
) -> Vec<(String, f64)> {
    let dims = cfg.timing_dims();
    KINDS
        .iter()
        .map(|&kind| {
            let mut s = make_scheduler(kind, 7);
            let (t, _) = timing::ours_step(&dims, clients, cuts, &cfg.server, s.as_mut());
            (s.name().to_string(), t)
        })
        .collect()
}

fn print_row(label: &str, ms: &[(String, f64)]) {
    let best = ms.iter().map(|(_, t)| *t).fold(f64::INFINITY, f64::min);
    let mut row = format!("{label:<26}");
    for (_, t) in ms {
        row.push_str(&format!(" {t:>9.3}{}", if (*t - best).abs() < 1e-12 { "*" } else { " " }));
    }
    println!("{row}");
}

fn main() {
    let cfg = ExperimentConfig::paper();
    println!(
        "{:<26} {:>10} {:>10} {:>10} {:>10}   (* = best)\n",
        "ablation", "proposed", "fifo", "wf", "random"
    );

    // 1. Fleet size.
    for mult in [1usize, 2, 4, 8] {
        let mut clients = Vec::new();
        let mut cuts = Vec::new();
        for _ in 0..mult {
            for (d, k) in paper_fleet() {
                clients.push(ClientConfig { device: d, cut: Some(k), link: Link::paper_default() });
                cuts.push(k);
            }
        }
        print_row(&format!("fleet x{mult} ({} clients)", clients.len()), &makespans(&clients, &cuts, &cfg));
    }

    // 2. Heterogeneity spread: random fleets with TFLOPS in [lo, hi].
    println!();
    let mut rng = Rng::new(11);
    for (lo, hi, label) in [
        (1.0, 1.0, "homogeneous (1 TFLOPS)"),
        (0.5, 2.0, "mild spread (0.5-2)"),
        (0.2, 4.0, "strong spread (0.2-4)"),
    ] {
        let clients: Vec<ClientConfig> = (0..12)
            .map(|i| {
                let tf = lo + rng.uniform() * (hi - lo);
                ClientConfig {
                    device: DeviceProfile::new(&format!("dev{i}"), tf, 8192.0),
                    cut: Some(1 + i % 3),
                    link: Link::paper_default(),
                }
            })
            .collect();
        let cuts: Vec<usize> = clients.iter().map(|c| c.cut.unwrap()).collect();
        print_row(label, &makespans(&clients, &cuts, &cfg));
    }

    // 3. Link rate.
    println!();
    for rate in [20.0, 100.0, 500.0] {
        let clients: Vec<ClientConfig> = paper_fleet()
            .into_iter()
            .map(|(d, k)| ClientConfig { device: d, cut: Some(k), link: Link::new(rate, 5.0) })
            .collect();
        let cuts: Vec<usize> = clients.iter().map(|c| c.cut.unwrap()).collect();
        print_row(&format!("link {rate} Mbps"), &makespans(&clients, &cuts, &cfg));
    }

    // 4. Aggregation interval I: time overhead per round amortized.
    println!("\naggregation interval (time overhead amortized per round):");
    let dims = cfg.timing_dims();
    let cuts: Vec<usize> = paper_fleet().iter().map(|(_, k)| *k).collect();
    let agg = timing::aggregation_time(&dims, &cfg.clients, &cuts);
    let mut s = make_scheduler(SchedulerKind::Proposed, 7);
    let (step, _) = timing::ours_step(&dims, &cfg.clients, &cuts, &cfg.server, s.as_mut());
    for interval in [1usize, 2, 5, 10] {
        let per_round = 4.0 * step + agg / interval as f64;
        println!("  I={interval:<3} round={per_round:.3}s (agg share {:.1}%)", agg / interval as f64 / per_round * 100.0);
    }

    // 5. Scheduler decision cost itself (the L3 hot path).
    println!();
    let (clients, cuts): (Vec<_>, Vec<_>) = {
        let mut cl = Vec::new();
        let mut cu = Vec::new();
        for _ in 0..16 {
            for (d, k) in paper_fleet() {
                cl.push(ClientConfig { device: d, cut: Some(k), link: Link::paper_default() });
                cu.push(k);
            }
        }
        (cl, cu)
    };
    let dims = cfg.timing_dims();
    let jobs = timing::build_jobs(&dims, &clients, &cuts, &cfg.server);
    let mut order = Vec::with_capacity(jobs.len());
    for kind in KINDS {
        let mut s = make_scheduler(kind, 7);
        bench(&format!("order/{}/96-clients", s.name()), 10, 200, || {
            s.order_into(&jobs, &mut order);
        });
    }
}
