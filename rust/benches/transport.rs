//! Compression-frontier bench: top-k fraction × quantization × error
//! feedback sweep on the closed-form `transport::testbed` world,
//! recording the uplink-reduction / quality trade-off into
//! `BENCH_transport.json`.  Pure host-side — payloads run through the
//! real `Codec` (encode → hash verify → decode), so no PJRT artifacts
//! are needed.
//!
//!     cargo bench --bench transport                  # full sweep
//!     TRANSPORT_SMOKE=1 cargo bench --bench transport  # CI smoke (gate config only)
//!
//! The gate configuration (frac = 0.05, q8, error feedback) is the
//! acceptance gate (asserted in smoke runs too): ≥ 10× uplink reduction
//! at ≤ 1% quality delta vs the dense run.

use sfl::transport::testbed::{run, Scenario};
use sfl::transport::{CompressKind, QuantKind};

const GATE_FRAC: f64 = 0.05;
const GATE_QUANT: QuantKind = QuantKind::Q8;

fn main() {
    let smoke = std::env::var("TRANSPORT_SMOKE").map(|v| v == "1").unwrap_or(false);
    let fracs: &[f64] = if smoke { &[GATE_FRAC] } else { &[0.01, 0.05, 0.1, 0.25, 1.0] };
    let quants: &[QuantKind] =
        if smoke { &[GATE_QUANT] } else { &[QuantKind::F32, QuantKind::Q8, QuantKind::Q4] };
    let base = Scenario::default();
    let mut entries: Vec<(String, String)> = Vec::new();

    let dense = run(&base).expect("dense run");
    println!("transport dense: quality={:.6} (d0={:.3})", dense.quality, dense.d0);
    entries.push(("transport/quality/dense".into(), format!("{:.6}", dense.quality)));
    entries.push(("transport/up_bytes/dense".into(), dense.up_bytes.to_string()));

    let mut gate_checked = false;
    for &frac in fracs {
        for &quant in quants {
            for ef in [false, true] {
                if smoke && !ef {
                    continue;
                }
                let sc = Scenario {
                    compress: CompressKind::TopK,
                    topk_frac: frac,
                    quant,
                    error_feedback: ef,
                    ..base.clone()
                };
                let out = run(&sc).expect("scenario run");
                let delta = dense.quality - out.quality;
                let tag = format!(
                    "frac{}/{quant}/{}",
                    (frac * 100.0).round() as u64,
                    if ef { "ef" } else { "noef" }
                );
                println!(
                    "transport {tag}: ratio={:.2}x quality={:.6} delta={:+.6} ef_norm={:.6}",
                    out.ratio, out.quality, delta, out.ef_norm
                );
                entries.push((format!("transport/ratio/{tag}"), format!("{:.4}", out.ratio)));
                entries
                    .push((format!("transport/quality/{tag}"), format!("{:.6}", out.quality)));
                entries.push((format!("transport/delta/{tag}"), format!("{:.6}", delta)));
                entries
                    .push((format!("transport/ef_norm/{tag}"), format!("{:.6}", out.ef_norm)));
                entries
                    .push((format!("transport/up_bytes/{tag}"), out.up_bytes.to_string()));
                // Acceptance gate: the EXPERIMENTS.md §Transport config
                // must sit on the ≥10× / ≤1% frontier.
                if frac == GATE_FRAC && quant == GATE_QUANT && ef {
                    gate_checked = true;
                    assert!(
                        out.ratio >= 10.0,
                        "{tag}: uplink reduction {:.2}x below the 10x gate",
                        out.ratio
                    );
                    assert!(
                        delta <= 0.01,
                        "{tag}: quality delta {:.4} exceeds 1% (dense {:.4}, compressed {:.4})",
                        delta,
                        dense.quality,
                        out.quality
                    );
                    assert!(
                        out.ef_norm > 0.0,
                        "{tag}: error feedback must be carrying residual mass"
                    );
                }
            }
        }
    }
    assert!(gate_checked, "sweep must include the frac5/q8/ef gate configuration");
    println!("accept: frac5/q8/ef ≥ 10x uplink reduction at ≤ 1% quality delta");

    let mut json = String::from("{\n");
    for (i, (name, value)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        json.push_str(&format!("  \"{name}\": {value}{comma}\n"));
    }
    json.push_str("}\n");
    match std::fs::write("BENCH_transport.json", &json) {
        Ok(()) => println!("wrote BENCH_transport.json ({} entries)", entries.len()),
        Err(e) => eprintln!("could not write BENCH_transport.json: {e}"),
    }
}
