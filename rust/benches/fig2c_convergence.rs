//! Bench: regenerate **Fig. 2(c)** — convergence time per scheme
//! (SL, SFL, FIFO, WF, Ours), the paper's bar chart.
//!
//! Convergence = accuracy plateau (patience-based detector, §V-B).
//! Prints the bars and the paper's headline deltas.
//!
//!     cargo bench --bench fig2c_convergence

use sfl::config::{ExperimentConfig, SchedulerKind, SchemeKind};
use sfl::coordinator::{RunResult, Session};
use sfl::runtime::Engine;
use sfl::telemetry;
use sfl::util::bench::bench_once;
use std::path::Path;

fn main() {
    let engine = Engine::load(Path::new("artifacts"), "mini")
        .expect("run `make artifacts` first");
    engine.warmup(&[1, 2, 3]).unwrap();

    let mut cfg = ExperimentConfig::mini();
    cfg.train.max_rounds = std::env::var("SFL_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);
    cfg.train.steps_per_round = 4;
    cfg.train.eval_interval = 3;
    cfg.train.eval_batches = 8;
    cfg.train.lr = 5e-3;
    cfg.train.patience = 6;

    let variants: [(&str, SchemeKind, SchedulerKind); 5] = [
        ("SL", SchemeKind::Sl, SchedulerKind::Proposed),
        ("SFL", SchemeKind::Sfl, SchedulerKind::Proposed),
        ("FIFO", SchemeKind::Ours, SchedulerKind::Fifo),
        ("WF", SchemeKind::Ours, SchedulerKind::WorkloadFirst),
        ("Ours", SchemeKind::Ours, SchedulerKind::Proposed),
    ];
    let mut results: Vec<(&str, RunResult)> = Vec::new();
    for (name, scheme, sched) in variants {
        let mut c = cfg.clone();
        c.scheme = scheme;
        c.scheduler = sched;
        let mut session = Session::new(&engine, &c).unwrap();
        let (r, _) =
            bench_once(&format!("fig2c/{name}"), || session.run_to_convergence().unwrap());
        results.push((name, r));
    }

    let rows: Vec<(&str, &RunResult)> = results.iter().map(|(n, r)| (*n, r)).collect();
    let csv = telemetry::fig2c_csv(&rows);
    telemetry::write_result(Path::new("results"), "fig2c_convergence.csv", &csv).unwrap();

    println!("\nFig 2(c) — convergence time (virtual seconds):");
    let max = rows.iter().map(|(_, r)| r.total_time()).fold(0.0, f64::max);
    for (name, r) in &rows {
        let t = r.total_time();
        let bar = "#".repeat(((t / max) * 40.0) as usize);
        println!("  {name:<5} {t:10.1}s  {bar}");
    }
    let by: std::collections::HashMap<&str, &RunResult> = rows.iter().copied().collect();
    println!(
        "\ndeltas: vs SL -{:.0}% (paper -41%) | vs SFL -{:.1}% (paper -6.1%) | vs WF -{:.1}% (paper -5.5%) | vs FIFO -{:.1}% (paper -6.2%)",
        (1.0 - by["Ours"].total_time() / by["SL"].total_time()) * 100.0,
        (1.0 - by["Ours"].total_time() / by["SFL"].total_time()) * 100.0,
        (1.0 - by["Ours"].total_time() / by["WF"].total_time()) * 100.0,
        (1.0 - by["Ours"].total_time() / by["FIFO"].total_time()) * 100.0,
    );
}
