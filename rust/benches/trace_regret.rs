//! Non-stationary scheduling regret bench: for each trace kind
//! (random-walk MFU/link drift, diurnal cycles, Markov availability
//! churn), drive a 100-client synthetic fleet through the environment
//! timeline and score every policy's cumulative makespan regret against
//! the per-round clairvoyant oracle schedule (Alg. 2 on the true
//! current-time jobs).  Results land in `BENCH_trace.json` (see
//! EXPERIMENTS.md §Traces for the schema).  Pure timing model — no
//! artifacts needed.
//!
//!     cargo bench --bench trace_regret                 # full sweep
//!     TRACE_SMOKE=1 cargo bench --bench trace_regret   # CI smoke
//!
//! Acceptance (full run): on the random-walk trace the estimator-driven
//! policy must accumulate strictly less regret than the static nominal
//! model (asserted in-process; `tests/trace_env.rs` enforces the same
//! gate in the test suite).

use sfl::coordinator::regret::{run_regret, RegretConfig};
use sfl::trace::{TraceKind, TraceSpec};
use sfl::util::bench::bench_once;

fn spec_for(kind: TraceKind) -> TraceSpec {
    TraceSpec {
        kind,
        seed: 5,
        mfu_sigma: 0.08,
        link_sigma: 0.05,
        revert: 0.01,
        period: 600.0,
        amp: 0.4,
        jitter: 0.05,
        mean_up: 300.0,
        mean_down: 60.0,
        obs_noise_sigma: 0.1,
        replay_path: String::new(),
    }
}

fn main() {
    let smoke = std::env::var("TRACE_SMOKE").is_ok();
    let (n, rounds) = if smoke { (40, 25) } else { (100, 150) };
    let mut entries: Vec<(String, String)> = Vec::new();

    for kind in [TraceKind::RandomWalk, TraceKind::Diurnal, TraceKind::Markov] {
        let mut rc = RegretConfig::new(spec_for(kind));
        rc.n = n;
        rc.rounds = rounds;
        let (report, _) = bench_once(&format!("trace/regret/{kind}/n{n}"), || {
            run_regret(&rc).expect("regret harness failed")
        });
        // Cumulative regrets can legitimately be negative (Alg. 2 is a
        // greedy heuristic) — only print the ratio where it means
        // something.
        let ratio = if report.nominal > 1e-9 {
            format!("{:.4}", report.estimator / report.nominal)
        } else {
            "n/a".into()
        };
        println!(
            "trace regret {kind:<12} rounds={} oracle_total={:.3}s \
             estimator={:+.3}s nominal={:+.3}s random={:+.3}s (est/nom = {ratio})",
            report.rounds, report.oracle_total, report.estimator, report.nominal, report.random,
        );
        entries.push((format!("trace/rounds/{kind}"), format!("{}", report.rounds)));
        let total = report.oracle_total;
        entries.push((format!("trace/oracle_total/{kind}"), format!("{total:.6}")));
        // The oracle row is zero by construction — emitted so the json
        // schema lists every policy explicitly.
        for (policy, value) in [
            ("oracle", 0.0),
            ("estimator", report.estimator),
            ("nominal", report.nominal),
            ("random", report.random),
        ] {
            entries.push((format!("trace/regret/{policy}/{kind}"), format!("{value:.6}")));
        }

        if !smoke && kind == TraceKind::RandomWalk {
            // The acceptance gate on the full non-stationary run (the
            // same gate `tests/trace_env.rs` enforces at fixed config).
            assert!(
                report.estimator < report.nominal,
                "estimator-driven scheduling must beat the static nominal model on a \
                 random-walk fleet: {} vs {}",
                report.estimator,
                report.nominal
            );
        }
    }

    let mut json = String::from("{\n");
    for (i, (name, value)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        json.push_str(&format!("  \"{name}\": {value}{comma}\n"));
    }
    json.push_str("}\n");
    match std::fs::write("BENCH_trace.json", &json) {
        Ok(()) => println!("wrote BENCH_trace.json ({} entries)", entries.len()),
        Err(e) => eprintln!("could not write BENCH_trace.json: {e}"),
    }
}
