//! Robust-aggregation bench: attack type × attacker fraction × merge
//! kernel sweep on the closed-form `faults::testbed` world, recording
//! the fraction of clean-run final quality each defense recovers into
//! `BENCH_robust.json`.  Pure host-side — attacks run through the real
//! `FaultInjector`, defenses through the real `Committee` / sanitizer /
//! trimmed / clipped kernels, so no PJRT artifacts are needed.
//!
//!     cargo bench --bench robust               # full sweep
//!     ROBUST_SMOKE=1 cargo bench --bench robust  # CI smoke (frac 0.2 only)
//!
//! The 20%-attacker column is the acceptance gate (asserted in smoke
//! runs too): trimmed mean and norm clipping must recover ≥ 95% of the
//! clean run's final quality while plain FedAvg degrades below 0.8.

use sfl::faults::testbed::{run, Scenario};
use sfl::faults::{AggKind, AttackKind};

const GATE_FRAC: f64 = 0.2;
const CLIP_REL: f64 = 0.02;

fn main() {
    let smoke = std::env::var("ROBUST_SMOKE").map(|v| v == "1").unwrap_or(false);
    let fracs: &[f64] = if smoke { &[0.2] } else { &[0.1, 0.2, 0.3] };
    let base = Scenario::default();
    let mut entries: Vec<(String, String)> = Vec::new();

    let clean = run(&base).expect("clean run");
    println!("robust clean: quality={:.6} (d0={:.3})", clean.quality, clean.d0);
    entries.push(("robust/quality/clean".into(), format!("{:.6}", clean.quality)));
    let floor = 0.95 * clean.quality;

    for attack in [AttackKind::Corrupt, AttackKind::Scale, AttackKind::Stale] {
        for &frac in fracs {
            let attackers = (frac * base.n as f64).ceil() as usize;
            for agg in [AggKind::Mean, AggKind::Trimmed, AggKind::Clip] {
                let sc = Scenario {
                    attack,
                    frac,
                    agg,
                    // Defense sized to the threat: trim ⌈frac·n⌉ from
                    // each tail so every attacker can be discarded.
                    trim: if agg == AggKind::Trimmed { attackers } else { 0 },
                    clip_rel: if agg == AggKind::Clip { CLIP_REL } else { f64::INFINITY },
                    ..base.clone()
                };
                let out = run(&sc).expect("scenario run");
                let tag = format!("{attack}/frac{}/{agg}", (frac * 100.0).round() as u64);
                println!(
                    "robust {tag}: quality={:.6} recovery={:.4} trim_count={}",
                    out.quality,
                    out.quality / clean.quality,
                    out.trim_count
                );
                entries.push((format!("robust/quality/{tag}"), format!("{:.6}", out.quality)));
                entries
                    .push((format!("robust/trim_count/{tag}"), out.trim_count.to_string()));
                // Acceptance gate at 20% attackers (corrupt + scale):
                // robust kernels recover, plain FedAvg measurably degrades.
                if frac == GATE_FRAC && attack != AttackKind::Stale {
                    match agg {
                        AggKind::Mean => assert!(
                            out.quality < 0.8,
                            "{tag}: plain FedAvg should degrade under attack, got {:.4}",
                            out.quality
                        ),
                        _ => assert!(
                            out.quality >= floor,
                            "{tag}: quality {:.4} below 95% of clean {:.4}",
                            out.quality,
                            clean.quality
                        ),
                    }
                }
            }
        }
    }

    // Orthogonal defenses at the gate fraction, plain-mean merge: the
    // pre-merge sanitizer and a full-coverage verification committee
    // each recover the clean quality on their own.
    for attack in [AttackKind::Corrupt, AttackKind::Scale] {
        let sanitized = run(&Scenario {
            attack,
            frac: GATE_FRAC,
            sanitize: true,
            ..base.clone()
        })
        .expect("sanitize run");
        let verified = run(&Scenario {
            attack,
            frac: GATE_FRAC,
            verify_frac: 1.0,
            ..base.clone()
        })
        .expect("verify run");
        println!(
            "robust {attack}/frac20 defenses: sanitize quality={:.6} (rejected={}), \
             verify quality={:.6} (quarantined={})",
            sanitized.quality, sanitized.rejected, verified.quality, verified.quarantined
        );
        entries.push((
            format!("robust/quality/{attack}/frac20/sanitize"),
            format!("{:.6}", sanitized.quality),
        ));
        entries.push((
            format!("robust/rejected/{attack}/frac20/sanitize"),
            sanitized.rejected.to_string(),
        ));
        entries.push((
            format!("robust/quality/{attack}/frac20/verify"),
            format!("{:.6}", verified.quality),
        ));
        entries.push((
            format!("robust/quarantined/{attack}/frac20/verify"),
            verified.quarantined.to_string(),
        ));
        assert!(
            sanitized.quality >= floor,
            "{attack}: sanitizer quality {:.4} below 95% of clean",
            sanitized.quality
        );
        assert!(
            verified.quality >= floor,
            "{attack}: committee quality {:.4} below 95% of clean",
            verified.quality
        );
        assert_eq!(
            verified.quarantined, 2,
            "{attack}: full-coverage committee must quarantine both attackers"
        );
    }
    println!("accept: trimmed/clip/sanitize/verify ≥ 95% of clean at 20% attackers, mean < 0.8");

    let mut json = String::from("{\n");
    for (i, (name, value)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        json.push_str(&format!("  \"{name}\": {value}{comma}\n"));
    }
    json.push_str("}\n");
    match std::fs::write("BENCH_robust.json", &json) {
        Ok(()) => println!("wrote BENCH_robust.json ({} entries)", entries.len()),
        Err(e) => eprintln!("could not write BENCH_robust.json: {e}"),
    }
}
